"""Deterministic durability suite: segment-log framing, torn-tail
repair, checkpoint/recover bit-identity for every stream shape, the
dead-letter side stream, tick-cadence checkpoints, the replay(S) op,
and — the heart of the layer — an **exhaustive crash-point sweep**:
count the workload's crash surface with a never-firing countdown, then
kill at every single site and assert recover() lands on some prefix of
the uncrashed run's fingerprint history, and that continuing from that
prefix reconverges bit-identically to the uncrashed final state.

The hypothesis generalization of the sweep (random schedules, random
crash sites, shrinking) lives in tests/test_stream_crash_points.py.
The flake-hunter workflow re-runs both files 5x at REPRO_MAX_WORKERS=8.
"""
import os
import threading

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.api import default_deployment
from repro.runtime import fault
from repro.stream import durability as dur
from repro.stream.engine import SEQ_FIELD, ShardedStream, Stream


@pytest.fixture(autouse=True)
def _disarm():
    yield
    fault.disarm_crash_points()


def _feed_plain(stream, ops):
    for v in ops:
        stream.append({"a": v})


def _plain_ops(rng, n=6, cap=32):
    return [rng.normal(size=int(k))
            for k in rng.integers(1, cap + 5, n)]


# -- segment log -------------------------------------------------------------

def test_segment_log_roundtrip_and_roll(tmp_path):
    log = dur.SegmentLog(str(tmp_path), ("a", "b"), segment_bytes=200)
    rng = np.random.default_rng(0)
    batches = [{f: rng.normal(size=5) for f in ("a", "b")}
               for _ in range(7)]
    for i, cols in enumerate(batches):
        assert log.append(dur.KIND_APPEND, i * 5, 5, cols, 5) == i
    assert len(log._segments()) > 1          # tiny cap forced rolls
    recs = dur.SegmentLog(str(tmp_path), ("a", "b")).scan()
    assert [r.lsn for r in recs] == list(range(7))
    for rec, cols in zip(recs, batches):
        for f in ("a", "b"):
            np.testing.assert_array_equal(rec.cols[f], cols[f])
    # scan from a mid lsn
    assert [r.lsn for r in log.scan(start_lsn=4)] == [4, 5, 6]


def test_segment_log_torn_tail_detected_and_repaired(tmp_path):
    log = dur.SegmentLog(str(tmp_path), ("a",))
    log.append(dur.KIND_APPEND, 0, 3, {"a": np.ones(3)}, 3)
    log.append(dur.KIND_APPEND, 3, 2, {"a": np.ones(2)}, 2)
    log.close()
    # tear the last record's payload
    _, path = log._segments()[-1]
    os.truncate(path, os.path.getsize(path) - 4)
    assert [r.lsn for r in
            dur.SegmentLog(str(tmp_path), ("a",)).scan()] == [0]
    # a reopened log repairs the tear and reuses the lsn
    log2 = dur.SegmentLog(str(tmp_path), ("a",))
    assert log2.next_lsn == 1
    log2.append(dur.KIND_APPEND, 3, 4, {"a": np.zeros(4)}, 4)
    recs = log2.scan()
    assert [r.lsn for r in recs] == [0, 1]
    assert recs[1].nrows == 4


def test_segment_log_crc_corruption_stops_scan(tmp_path):
    log = dur.SegmentLog(str(tmp_path), ("a",))
    for i in range(3):
        log.append(dur.KIND_APPEND, i, 1, {"a": np.full(1, i)}, 1)
    log.close()
    _, path = log._segments()[0]
    with open(path, "r+b") as f:           # flip one payload byte of rec 1
        f.seek(dur._HDR.size * 2 + 8 + 3)
        b = f.read(1)
        f.seek(-1, 1)
        f.write(bytes([b[0] ^ 0xFF]))
    assert [r.lsn for r in
            dur.SegmentLog(str(tmp_path), ("a",)).scan()] == [0]


def test_truncate_from_and_prune(tmp_path):
    log = dur.SegmentLog(str(tmp_path), ("a",), segment_bytes=64)
    for i in range(10):
        log.append(dur.KIND_APPEND, i, 1, {"a": np.full(1, i)}, 1)
    log.truncate_from(6)
    assert [r.lsn for r in log.scan()] == list(range(6))
    assert log.next_lsn == 6
    log.append(dur.KIND_APPEND, 6, 1, {"a": np.zeros(1)}, 1)
    assert [r.lsn for r in log.scan()] == list(range(7))
    nseg = len(log._segments())
    log.prune_below(5)
    assert len(log._segments()) < nseg
    assert [r.lsn for r in log.scan(5)] == [5, 6]


# -- checkpoint/recover bit-identity per stream shape ------------------------

def test_plain_recover_bit_identical(tmp_path):
    rng = np.random.default_rng(1)
    ops = _plain_ops(rng)
    s = Stream("t", ("a",), 32)
    h = dur.attach(s, str(tmp_path))
    for i, v in enumerate(ops):
        s.append({"a": v})
        if i == 2:
            h.checkpoint()
    r = dur.recover(str(tmp_path))
    assert dur.fingerprint(r.stream) == dur.fingerprint(s)
    assert r.checkpoint_step == 1 and r.rows_replayed > 0
    # rolling aggregates reproduce too, not just counters
    assert (s.window(8).aggregate("sum", "a")
            == r.stream.window(8).aggregate("sum", "a"))


def test_plain_recover_without_checkpoint(tmp_path):
    s = Stream("t", ("a",), 16)
    dur.attach(s, str(tmp_path))
    _feed_plain(s, _plain_ops(np.random.default_rng(2), n=4, cap=16))
    r = dur.recover(str(tmp_path))
    assert r.checkpoint_step is None
    assert dur.fingerprint(r.stream) == dur.fingerprint(s)


def test_sharded_recover_bit_identical(tmp_path):
    rng = np.random.default_rng(3)
    shards = [(f"e{i}", Stream(f"w@shard{i}", ("a", "b", SEQ_FIELD), 64))
              for i in range(3)]
    ss = ShardedStream("w", ("a", "b"), shards, block_rows=8)
    h = dur.attach(ss, str(tmp_path))
    for i in range(9):
        n = int(rng.integers(1, 30))
        ss.append({"a": rng.normal(size=n), "b": rng.normal(size=n)})
        if i == 4:
            h.checkpoint()
    r = dur.recover(str(tmp_path))
    assert dur.fingerprint(r.stream) == dur.fingerprint(ss)
    # seq assignment is part of the identity: gathers agree exactly
    np.testing.assert_array_equal(
        np.asarray(ss.window(40).attrs["a"]),
        np.asarray(r.stream.window(40).attrs["a"]))


def test_event_time_recover_with_late_and_flush(tmp_path):
    rng = np.random.default_rng(4)
    s = Stream("e", ("ts", "v"), 64, ts_field="ts", max_delay=2.0)
    h = dur.attach(s, str(tmp_path))
    ts = np.arange(40, dtype=float)
    ts[5], ts[6] = ts[6], ts[5]            # bounded disorder
    for k in range(0, 40, 8):
        s.append({"ts": ts[k:k + 8], "v": rng.normal(size=8)})
        if k == 16:
            h.checkpoint()
    s.append({"ts": np.array([0.5]), "v": np.array([9.0])})   # late
    s.flush(50.0)
    r = dur.recover(str(tmp_path))
    assert dur.fingerprint(r.stream) == dur.fingerprint(s)
    assert r.stream.total_late == 1
    assert r.stream.watermark == 50.0


def test_sharded_event_time_recover(tmp_path):
    rng = np.random.default_rng(5)
    shards = [(f"e{i}", Stream(f"x@shard{i}", ("ts", "v", SEQ_FIELD), 64,
                               ts_field="ts"))
              for i in range(2)]
    ss = ShardedStream("x", ("ts", "v"), shards, block_rows=8,
                       ts_field="ts", max_delay=4.0)
    h = dur.attach(ss, str(tmp_path))
    ts = np.arange(48, dtype=float)
    for k in range(0, 48, 6):
        ss.append({"ts": ts[k:k + 6], "v": rng.normal(size=6)})
        if k == 24:
            h.checkpoint()
    ss.flush(60.0)
    r = dur.recover(str(tmp_path))
    assert dur.fingerprint(r.stream) == dur.fingerprint(ss)


def test_recover_after_wal_prune(tmp_path):
    """keep-last-k pruning must never strand a retained checkpoint
    without its log tail."""
    rng = np.random.default_rng(6)
    s = Stream("p", ("a",), 16)
    h = dur.attach(s, str(tmp_path), keep=2, segment_bytes=256)
    for i in range(30):
        s.append({"a": rng.normal(size=8)})
        if i % 10 == 9:
            h.checkpoint()
    assert h.manager.all_steps() == [2, 3]   # keep-last-2 held
    assert h.stats()["segments"] < 8         # wal actually pruned
    r = dur.recover(str(tmp_path))
    assert dur.fingerprint(r.stream) == dur.fingerprint(s)


# -- exhaustive crash-point sweep --------------------------------------------

def _crash_workload(tmp_path, ops):
    """The canonical sweep workload: plain durable stream, a mid-run
    blocking checkpoint."""
    s = Stream("t", ("a",), 32)
    h = dur.attach(s, str(tmp_path))
    for i, v in enumerate(ops):
        s.append({"a": v})
        if i == 2:
            h.checkpoint()
    return s


def test_crash_at_every_point_recovers_a_prefix(tmp_path):
    """Kill the workload at EVERY crash site (log write boundaries,
    checkpoint begin/promote/gc/prune) and require: (1) recover() is
    bit-identical to some prefix of the uncrashed run, (2) re-running
    the remaining ops reconverges to the uncrashed final state, (3) a
    second recovery of the continued log also matches — the log the
    continuation wrote is itself consistent."""
    rng = np.random.default_rng(7)
    ops = _plain_ops(rng)
    ref = Stream("t", ("a",), 32)
    snaps = [dur.fingerprint(ref)]
    for v in ops:
        ref.append({"a": v})
        snaps.append(dur.fingerprint(ref))

    fault.arm_crash_point("stream/*", at_hit=10 ** 9)
    _crash_workload(tmp_path / "count", ops)
    surface = len(fault.disarm_crash_points()["hits"])
    assert surface >= len(ops), "crash surface suspiciously small"

    for k in range(1, surface + 1):
        d = tmp_path / f"k{k}"
        fault.arm_crash_point("stream/*", at_hit=k)
        try:
            _crash_workload(d, ops)
            crashed = False
        except fault.SimulatedCrash:
            crashed = True
        report = fault.disarm_crash_points()
        assert crashed and report["fired"] is not None, k
        r = dur.recover(str(d))
        fp = dur.fingerprint(r.stream)
        assert fp in snaps, \
            f"hit {k} ({report['fired']}): no prefix matches"
        p = snaps.index(fp)
        dur.attach(r.stream, str(d))
        for v in ops[p:]:
            r.stream.append({"a": v})
        assert dur.fingerprint(r.stream) == snaps[-1], k
        assert dur.fingerprint(dur.recover(str(d)).stream) == snaps[-1]


def test_crash_inside_checkpoint_manager(tmp_path):
    """Kill between the manifest write and the atomic promote, and
    between promote and gc: the previous checkpoint must stay live and
    recovery must still converge."""
    rng = np.random.default_rng(8)
    ops = _plain_ops(rng)
    for point in ("checkpoint/promote", "checkpoint/gc"):
        d = tmp_path / point.replace("/", "_")
        fault.arm_crash_point(point, at_hit=1)
        with pytest.raises(fault.SimulatedCrash):
            _crash_workload(d, ops)
        fault.disarm_crash_points()
        r = dur.recover(str(d))
        dur.attach(r.stream, str(d))
        # finish the run from wherever the prefix landed: the recovered
        # stream accepts ingest and a fresh checkpoint cleanly
        r.stream.append({"a": np.ones(5)})
        r.stream._durable.checkpoint()
        r2 = dur.recover(str(d))
        assert dur.fingerprint(r2.stream) == dur.fingerprint(r.stream)


def test_sharded_crash_cuts_incomplete_block(tmp_path):
    """A kill between two shard-lane log appends leaves a block only
    partially logged; recovery must cut it (and everything after) on
    every lane, then continue consistently."""
    rng = np.random.default_rng(9)

    def build(d):
        shards = [(f"e{i}",
                   Stream(f"w@shard{i}", ("a", SEQ_FIELD), 64))
                  for i in range(2)]
        ss = ShardedStream("w", ("a",), shards, block_rows=4)
        dur.attach(ss, str(d))
        return ss

    batches = [rng.normal(size=10) for _ in range(3)]  # span both shards

    # uncrashed reference: fingerprint after every append
    ref_shards = [(f"e{i}", Stream(f"w@shard{i}", ("a", SEQ_FIELD), 64))
                  for i in range(2)]
    ref = ShardedStream("w", ("a",), ref_shards, block_rows=4)
    snaps = [dur.fingerprint(ref)]
    for v in batches:
        ref.append({"a": v})
        snaps.append(dur.fingerprint(ref))

    # count the crash surface
    fault.arm_crash_point("stream/log:*", at_hit=10 ** 9)
    ss = build(tmp_path / "count")
    for v in batches:
        ss.append({"a": v})
    surface = len(fault.disarm_crash_points()["hits"])
    assert surface >= 2 * len(batches)        # >= one site per lane

    for k in range(1, surface + 1):
        d = tmp_path / f"k{k}"
        ss = build(d)
        fault.arm_crash_point("stream/log:*", at_hit=k)
        try:
            for v in batches:
                ss.append({"a": v})
        except fault.SimulatedCrash:
            pass
        fault.disarm_crash_points()
        r = dur.recover(str(d))
        rs = r.stream
        # whatever survived is a whole-block prefix of the reference:
        # incomplete blocks were cut, so some append-prefix matches
        assert rs.total_appended % 10 == 0
        assert dur.fingerprint(rs) in snaps, k
        # and the repaired log re-recovers to the same state
        assert (dur.fingerprint(dur.recover(str(d)).stream)
                == dur.fingerprint(rs))


# -- dead-letter side stream -------------------------------------------------

def test_dead_letter_stream_queryable_and_replayed(tmp_path):
    bd = default_deployment()
    s = bd.register_stream("streamstore0", "icu.abp", ("ts", "v"),
                           capacity=128, ts_field="ts", max_delay=1.0,
                           durability=str(tmp_path), dead_letter=True)
    s.append({"ts": np.arange(8, dtype=float), "v": np.zeros(8)})
    s.append({"ts": np.array([0.5, 7.5]), "v": np.array([1.0, 2.0])})
    assert s.total_late == 1
    late = bd.query("bdstream(snapshot(icu.abp.__late))").value
    np.testing.assert_array_equal(np.asarray(late.columns["ts"]), [0.5])
    np.testing.assert_array_equal(np.asarray(late.columns["v"]), [1.0])
    # replay preserves the dead letters bit-for-bit
    fp = dur.fingerprint(s)
    s._durable.close()
    bd2 = default_deployment()
    r = bd2.recover_stream("streamstore0", str(tmp_path))
    assert dur.fingerprint(r) == fp
    late2 = bd2.query("bdstream(snapshot(icu.abp.__late))").value
    np.testing.assert_array_equal(np.asarray(late2.columns["ts"]),
                                  [0.5])


def test_dead_letter_without_durability():
    bd = default_deployment()
    s = bd.register_stream("streamstore0", "icu.ecg", ("ts", "v"),
                           capacity=64, ts_field="ts", max_delay=0.5,
                           dead_letter=True)
    s.append({"ts": np.arange(4, dtype=float), "v": np.zeros(4)})
    s.append({"ts": np.array([0.25]), "v": np.array([3.0])})
    late = bd.query("bdstream(snapshot(icu.ecg.__late))").value
    assert np.asarray(late.columns["v"]).tolist() == [3.0]


# -- cadence, API recovery, replay op ----------------------------------------

def test_tick_cadence_checkpoints_and_monitor_feed(tmp_path):
    bd = default_deployment()
    s = bd.register_stream("streamstore0", "vitals.stream",
                           ("patient", "hr"), capacity=1024, shards=2,
                           durability=str(tmp_path),
                           checkpoint_every_rows=200)
    rng = np.random.default_rng(10)
    for _ in range(6):
        s.append({"patient": rng.integers(0, 8, 96).astype(float),
                  "hr": 75 + rng.standard_normal(96)})
        bd.streams.tick()
    s._durable.manager.wait()
    assert s._durable.checkpoints >= 2      # 576 rows / 200 cadence
    snap = bd.monitor.snapshot()
    stats = snap["durability_stats"]["vitals.stream"]
    assert stats["log_rows"] == 576
    assert stats["checkpoints"] >= 2
    # and the full status() render carries the block
    from repro.core import admin
    st = admin.status(bd)
    assert "vitals.stream" in st["streams"]["durability"]


def test_recover_stream_api_sharded(tmp_path):
    bd = default_deployment()
    s = bd.register_stream("streamstore0", "vitals.stream",
                           ("patient", "hr"), capacity=1024, shards=2,
                           durability=str(tmp_path),
                           checkpoint_every_rows=200)
    rng = np.random.default_rng(11)
    for _ in range(4):
        s.append({"patient": rng.integers(0, 8, 96).astype(float),
                  "hr": 75 + rng.standard_normal(96)})
        bd.streams.tick()
    fp = dur.fingerprint(s)
    win = np.asarray(s.window(64).attrs["hr"])
    s._durable.close()
    bd2 = default_deployment()
    r = bd2.recover_stream("streamstore0", str(tmp_path))
    assert dur.fingerprint(r) == fp
    np.testing.assert_array_equal(np.asarray(r.window(64).attrs["hr"]),
                                  win)
    # the recovered stream is live: ingest + standing queries continue
    r.append({"patient": np.zeros(8), "hr": np.full(8, 80.0)})
    out = bd2.query(
        "bdstream(aggregate(window(vitals.stream, 8), avg(hr)))").value
    assert abs(float(np.asarray(
        next(iter(out.attrs.values()))).ravel()[0]) - 80.0) < 1e-12
    assert bd2.monitor.snapshot()["recoveries"]["vitals.stream"][
        "rows_replayed"] >= 0


def test_replay_op_reports_identical(tmp_path):
    bd = default_deployment()
    s = bd.register_stream("streamstore0", "vitals.stream", ("hr",),
                           capacity=256, durability=str(tmp_path))
    rng = np.random.default_rng(12)
    for _ in range(5):
        s.append({"hr": rng.normal(size=20)})
    s._durable.checkpoint()
    s.append({"hr": rng.normal(size=20)})     # tail past the checkpoint
    out = bd.query("bdstream(replay(vitals.stream))").value
    row = {k: float(v[0]) for k, v in out.columns.items()}
    assert row["identical"] == 1.0
    assert row["rows"] == 20.0                # only the tail replays
    assert row["rows_per_second"] > 0.0


def test_replay_op_requires_durability():
    bd = default_deployment()
    bd.register_stream("streamstore0", "plain.stream", ("x",),
                       capacity=16)
    from repro.core.executor import LocalQueryExecutionException
    with pytest.raises(LocalQueryExecutionException,
                       match="no durability"):
        bd.query("bdstream(replay(plain.stream))")


def test_obs_spans_and_metrics_emitted(tmp_path):
    from repro.obs import metrics, trace
    trace.set_enabled(True)
    trace.reset()
    try:
        s = Stream("obs", ("a",), 32)
        h = dur.attach(s, str(tmp_path))
        s.append({"a": np.ones(4)})
        h.checkpoint()
        dur.recover(str(tmp_path))
        names = {r.name for r in trace.spans()}
        assert {"stream/log_append", "stream/checkpoint",
                "stream/replay"} <= names
    finally:
        trace.set_enabled(False)
    text = metrics.prometheus_text()
    assert "repro_stream_log_records_total" in text
    assert "repro_stream_checkpoints_total" in text
    assert "repro_stream_recoveries_total" in text


# -- CheckpointManager async-save regression ---------------------------------

def test_checkpoint_manager_joins_pending_before_next_save(tmp_path):
    """Regression: save(blocking=False) left _pending unjoined, so the
    next save's keep-last-k prune could delete the in-flight .tmp (or
    even the newer promoted step) mid-write.  Now every save joins the
    pending thread first, and _write itself is serialized."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    release = threading.Event()
    entered = threading.Event()
    real_write = mgr._write

    def slow_write(step, state):
        entered.set()
        release.wait(timeout=10)
        return real_write(step, state)

    mgr._write = slow_write
    mgr.save(1, {"x": np.arange(4)}, blocking=False)
    assert entered.wait(timeout=10)

    done = threading.Event()

    def second_save():
        mgr.save(2, {"x": np.arange(8)})      # blocking
        done.set()

    t = threading.Thread(target=second_save, daemon=True)
    t.start()
    # the blocking save must be parked on the join, not racing ahead
    assert not done.wait(timeout=0.3)
    release.set()
    t.join(timeout=10)
    assert done.is_set()
    assert mgr.all_steps() == [2]             # keep=1 pruned step 1
    assert not [p for p in os.listdir(str(tmp_path))
                if p.endswith(".tmp")]        # no half-written debris
    state, step = mgr.restore({"x": np.zeros(8, dtype=np.int64)})
    assert step == 2
    np.testing.assert_array_equal(state["x"], np.arange(8))


def test_checkpoint_manager_restore_flat(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, {"a": np.arange(3), "b": {"c": np.ones(2)}})
    flat = mgr.restore_flat()
    np.testing.assert_array_equal(flat["a"], np.arange(3))
    np.testing.assert_array_equal(flat["b/c"], np.ones(2))
    with pytest.raises(FileNotFoundError):
        CheckpointManager(str(tmp_path / "empty")).restore_flat()
