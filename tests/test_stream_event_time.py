"""Event-time streaming tests (arXiv:1609.07548: S-Store as the
polystore's time-ordered engine): bounded out-of-order ingest through
insertion buffers, per-stream low watermarks (min across shards),
``ewindow`` views closed only when the watermark passes, cross-stream
interval ``join`` — including the acceptance criterion that a join of
two sharded, out-of-order streams is bit-identical to the same join on
the unsharded, pre-sorted inputs — watermark-gated standing queries with
per-query late-row accounting, and the Planner's join home-engine pin.
"""
import numpy as np
import pytest

from repro.core import admin, bql
from repro.core.api import default_deployment
from repro.stream import shim
from repro.stream.engine import (ShardedStream, Stream, StreamEngine,
                                 StreamException)


def _jittered(ts, rng, jitter):
    """Arrival order of event times under bounded network jitter."""
    return np.argsort(ts + rng.uniform(-jitter, jitter, ts.shape[0]))


# -- out-of-order ingest ------------------------------------------------------
def test_plain_stream_append_counts_unchanged():
    """Streams without ts_field keep the exact PR-3 seq semantics —
    including the append result schema (no event-time keys)."""
    s = Stream("s", ("x",), capacity=8)
    assert s.append({"x": [1.0, 2.0]}) == {"appended": 2, "dropped": 0,
                                           "rows": 2}
    assert s.append({"x": []}) == {"appended": 0, "dropped": 0, "rows": 2}
    assert s.ts_field is None and s._pending_rows == 0
    with pytest.raises(StreamException):
        s.flush()                          # no event-time field
    with pytest.raises(StreamException):
        s.ewindow(4.0)


def test_out_of_order_rows_flush_in_ts_order():
    s = Stream("s", ("ts", "x"), capacity=64, ts_field="ts",
               max_delay=3.0)
    r = s.append({"ts": [5.0, 2.0, 7.0, 1.0], "x": [50, 20, 70, 10]})
    # watermark = 7 - 3 = 4: ts 1,2 flushed in order; 5,7 still pending
    assert r == {"appended": 4, "dropped": 0, "late": 0, "flushed": 2,
                 "pending": 2, "rows": 2}
    assert s.watermark == 4.0
    snap = s.snapshot()
    np.testing.assert_array_equal(np.asarray(snap.columns["ts"]), [1, 2])
    np.testing.assert_array_equal(np.asarray(snap.columns["seq"]),
                                  [0, 1])                # seq at flush
    s.append({"ts": [12.0], "x": [120]})   # wm -> 9: 5,7 flush
    np.testing.assert_array_equal(
        np.asarray(s.snapshot().columns["ts"]), [1, 2, 5, 7])


def test_equal_timestamps_keep_arrival_order():
    s = Stream("s", ("ts", "x"), capacity=64, ts_field="ts",
               max_delay=0.0)
    s.append({"ts": [3.0, 3.0, 3.0], "x": [1.0, 2.0, 3.0]})
    np.testing.assert_array_equal(
        np.asarray(s.snapshot().columns["x"]), [1, 2, 3])


def test_late_rows_dropped_and_counted():
    s = Stream("s", ("ts", "x"), capacity=64, ts_field="ts",
               max_delay=2.0)
    s.append({"ts": [10.0], "x": [1.0]})          # wm = 8
    r = s.append({"ts": [5.0, 9.0], "x": [2.0, 3.0]})   # 5 < 8: late
    assert r["late"] == 1 and r["appended"] == 1
    assert s.total_late == 1
    s.flush()
    np.testing.assert_array_equal(
        np.asarray(s.snapshot().columns["ts"]), [9, 10])


def test_flush_punctuation_closes_the_tail():
    s = Stream("s", ("ts", "x"), capacity=64, ts_field="ts",
               max_delay=100.0)
    s.append({"ts": np.arange(8, dtype=float), "x": np.zeros(8)})
    assert s.num_rows == 0                 # all pending: wm = 7 - 100
    out = s.flush()                        # punctuation: wm -> max ts
    assert out["flushed"] == 8 and out["watermark"] == 7.0
    assert s.num_rows == 8
    with pytest.raises(StreamException):
        Stream("p", ("x",), capacity=4).flush()


def test_seq_windows_still_work_on_event_time_streams():
    """seq is assigned at flush in ts order, so the seq-aligned ops keep
    working — and coincide with event order."""
    s = Stream("s", ("ts", "x"), capacity=64, ts_field="ts",
               max_delay=0.0)
    rng = np.random.default_rng(0)
    ts = np.arange(16, dtype=float)
    order = _jittered(ts, rng, 0.0)        # in order, delay 0
    s.append({"ts": ts[order], "x": (ts * 2)[order]})
    w = s.window(8)                        # seq window [8, 16)
    np.testing.assert_array_equal(np.asarray(w.attrs["ts"]),
                                  np.arange(8, 16))
    assert s.window_aggregate(8, "avg", "x") == pytest.approx(23.0)


# -- ewindow ------------------------------------------------------------------
def test_ewindow_closed_only_when_watermark_passes():
    s = Stream("s", ("ts", "x"), capacity=64, ts_field="ts",
               max_delay=2.0)
    with pytest.raises(StreamException):
        s.ewindow(4.0)                     # watermark not started
    s.append({"ts": [0.0, 1.0, 3.0], "x": [0, 1, 3]})   # wm = 1
    with pytest.raises(StreamException):
        s.ewindow(4.0)                     # [0,4) not closed at wm=1
    s.append({"ts": [6.5], "x": [65]})     # wm = 4.5: [0,4) closes
    w = s.ewindow(4.0)
    np.testing.assert_array_equal(np.asarray(w.attrs["ts"]), [0, 1, 3])
    assert w.dim_names == ("tick",)
    s.append({"ts": [10.5], "x": [105]})   # wm = 8.5: latest = [4,8)
    np.testing.assert_array_equal(
        np.asarray(s.ewindow(4.0).attrs["ts"]), [6.5])
    # slide alignment: latest [k*2, k*2+4) with end <= 8.5 is [4,8)
    np.testing.assert_array_equal(
        np.asarray(s.ewindow(4.0, 2.0).attrs["ts"]), [6.5])


def test_ewindow_may_be_empty_and_row_count_varies():
    """Event-time windows have density-dependent row counts; an empty
    closed window is legitimate (no readings in that span)."""
    s = Stream("s", ("ts", "x"), capacity=64, ts_field="ts",
               max_delay=0.0)
    s.append({"ts": [1.0, 2.0, 9.0], "x": [1, 2, 9]})   # wm = 9
    assert np.asarray(s.ewindow(4.0).attrs["ts"]).shape[0] == 0  # [4,8)
    np.testing.assert_array_equal(
        np.asarray(s.ewindow(8.0).attrs["ts"]), [1, 2])


def test_ewindow_evicted_window_raises():
    s = Stream("s", ("ts", "x"), capacity=6, ts_field="ts",
               max_delay=0.0)
    s.append({"ts": np.arange(8, dtype=float), "x": np.zeros(8)})
    # ring kept ts 2..7; the latest closed window [0,4) lost ts 0,1 to
    # eviction — no silent partials
    with pytest.raises(StreamException):
        s.ewindow(4.0)
    s.append({"ts": [11.0], "x": [0.0]})   # wm=11: latest closed = [4,8)
    np.testing.assert_array_equal(
        np.asarray(s.ewindow(4.0).attrs["ts"]), [4, 5, 6, 7])


# -- sharded event time -------------------------------------------------------
def _mk_sharded(name, fields, shards, capacity=256, shard_key=None,
                block_rows=4, ts_field="ts", max_delay=3.0):
    engines = [StreamEngine(f"streamstore{i}") for i in range(shards)]
    parts = [(e.name, e.create_stream(f"{name}@shard{i}",
                                      tuple(fields) + ("__seq",),
                                      -(-capacity // shards)))
             for i, e in enumerate(engines)]
    return ShardedStream(name, fields, parts, shard_key=shard_key,
                         block_rows=block_rows, ts_field=ts_field,
                         max_delay=max_delay)


def test_sharded_out_of_order_gather_bit_identical_to_unsharded():
    ref = Stream("s", ("ts", "x"), capacity=256, ts_field="ts",
                 max_delay=3.0)
    sh = _mk_sharded("s", ("ts", "x"), shards=3)
    rng = np.random.default_rng(1)
    ts = np.arange(96, dtype=float)
    order = _jittered(ts, rng, 1.4)
    for a in range(0, 96, 16):
        sl = order[a:a + 16]
        batch = {"ts": ts[sl], "x": np.sin(ts[sl])}
        ref.append(dict(batch))
        sh.append(dict(batch))
    ref.flush()
    sh.flush()
    for view in (lambda s: s.snapshot().columns["ts"],
                 lambda s: s.snapshot().columns["x"],
                 lambda s: s.snapshot().columns["seq"],
                 lambda s: s.ewindow(16.0).attrs["x"],
                 lambda s: s.window(32).attrs["x"]):
        np.testing.assert_array_equal(np.asarray(view(ref)),
                                      np.asarray(view(sh)))


def test_sharded_watermark_is_min_across_keyed_shards():
    sh = _mk_sharded("kh", ("ts", "k"), shards=2, shard_key="k",
                     max_delay=0.0)
    # key 0 -> shard 0 (max ts 10), key 1 -> shard 1 (max ts 2)
    sh.append({"ts": [10.0, 2.0], "k": [0.0, 1.0]})
    assert sh.watermark == 2.0             # min across shards with data
    st = sh.stats()
    assert st["shard_watermarks"] == {0: 10.0, 1: 2.0}
    assert st["watermark"] == 2.0 and st["pending"] == 1
    sh.append({"ts": [11.0], "k": [1.0]})  # the lagging shard catches up
    assert sh.watermark == 10.0
    # a never-seen shard must not hold the watermark at -inf forever:
    sh2 = _mk_sharded("kh2", ("ts", "k"), shards=2, shard_key="k",
                      max_delay=0.0)
    sh2.append({"ts": [5.0, 6.0], "k": [0.0, 2.0]})   # both hash shard 0
    assert sh2.watermark == 6.0


# -- cross-stream interval join ----------------------------------------------
def _feed_pair(bd, rng, *, shards_a, shards_b, rows=96, jitter=1.8,
               max_delay=6.0, presorted=False):
    """Two event-time streams over one deployment: jittered out-of-order
    delivery, or the pre-sorted in-order reference."""
    a = bd.register_stream("streamstore0", "j.abp", ("ts", "abp"),
                           capacity=4 * rows, shards=shards_a,
                           ts_field="ts", max_delay=max_delay)
    b = bd.register_stream("streamstore0", "j.ecg", ("ts", "ecg"),
                           capacity=4 * rows, shards=shards_b,
                           ts_field="ts", max_delay=max_delay)
    ts = np.arange(rows, dtype=float)
    va = 90.0 + np.sin(ts)
    tb = ts + 0.25
    vb = np.cos(ts)
    oa = np.arange(rows) if presorted else _jittered(ts, rng, jitter)
    ob = np.arange(rows) if presorted else _jittered(tb, rng, jitter)
    for s in range(0, rows, 16):
        a.append({"ts": ts[oa][s:s + 16], "abp": va[oa][s:s + 16]})
        b.append({"ts": tb[ob][s:s + 16], "ecg": vb[ob][s:s + 16]})
    a.flush()
    b.flush()
    return a, b


JOIN_Q = ("bdstream(join(ewindow(j.abp, 24), ewindow(j.ecg, 24),"
          " on=ts, tol=0.5))")


def test_join_bit_identical_sharded_out_of_order_vs_unsharded_presorted():
    """The acceptance criterion: joining two sharded streams fed out of
    order is bit-identical to the same join computed on the unsharded,
    pre-sorted inputs."""
    bd_ref = default_deployment()
    _feed_pair(bd_ref, np.random.default_rng(2), shards_a=1, shards_b=1,
               presorted=True)
    bd_sh = default_deployment()
    _feed_pair(bd_sh, np.random.default_rng(3), shards_a=3, shards_b=2)
    ref = bd_ref.query(JOIN_Q).value
    cur = bd_sh.query(JOIN_Q).value
    assert sorted(cur.columns) == sorted(ref.columns)
    assert len(np.asarray(cur.columns["dt"])) > 0
    for col in ref.columns:
        np.testing.assert_array_equal(np.asarray(ref.columns[col]),
                                      np.asarray(cur.columns[col]))
    assert bd_sh.engines["streamstore0"].get("j.abp").total_late == 0


def test_interval_join_tol_semantics():
    left = shim.dm.ArrayObject({"ts": shim.jnp.asarray([0.0, 5.0]),
                                "a": shim.jnp.asarray([1.0, 2.0])},
                               ("tick",))
    right = shim.dm.ArrayObject({"ts": shim.jnp.asarray([0.5, 4.0, 9.0]),
                                 "b": shim.jnp.asarray([10., 20., 30.])},
                                ("tick",))
    out = shim.interval_join(left, right, on="ts", tol=1.0)
    # |0-0.5|<=1 and |5-4|<=1 (inclusive bound); nothing matches 9
    np.testing.assert_array_equal(np.asarray(out.columns["l_a"]), [1, 2])
    np.testing.assert_array_equal(np.asarray(out.columns["r_b"]),
                                  [10, 20])
    np.testing.assert_array_equal(np.asarray(out.columns["dt"]),
                                  [0.5, -1.0])
    empty = shim.interval_join(left, right, on="ts", tol=0.1)
    assert np.asarray(empty.columns["dt"]).shape[0] == 0
    with pytest.raises(StreamException):
        shim.interval_join(left, right, on="nope")
    with pytest.raises(StreamException):
        shim.interval_join(left, right, tol=-1.0)


def test_colocated_partial_join_identical_and_counted():
    """Co-located sharded operands take the banded partial path; the
    result is bit-identical to the single-band join."""
    bd = default_deployment()
    _feed_pair(bd, np.random.default_rng(4), shards_a=2, shards_b=2)
    before = dict(shim.JOIN_STATS)
    via_bql = bd.query(JOIN_Q).value
    assert shim.JOIN_STATS["partial_joins"] == before["partial_joins"] + 1
    a = bd.engines["streamstore0"].get("j.abp")
    b = bd.engines["streamstore0"].get("j.ecg")
    assert a.shard_engines() == b.shard_engines()
    full = shim.interval_join(a.ewindow(24.0), b.ewindow(24.0),
                              on="ts", tol=0.5, bands=1)
    for col in full.columns:
        np.testing.assert_array_equal(np.asarray(full.columns[col]),
                                      np.asarray(via_bql.columns[col]))


def test_join_rides_staged_cast_to_relational():
    bd = default_deployment()
    _feed_pair(bd, np.random.default_rng(5), shards_a=2, shards_b=1)
    r = bd.query("bdrel(select l_ts, r_ecg from bdcast(" + JOIN_Q[:-1]
                 + "), j_tbl, '', relational) where l_ts >= 40)")
    lts = np.asarray(r.value.columns["l_ts"])
    assert lts.shape[0] > 0 and (lts >= 40).all()


def test_join_of_seq_windows_and_snapshots():
    """join accepts any window views — on= picks the shared field."""
    bd = default_deployment()
    s1 = bd.register_stream("streamstore0", "a.stream", ("t", "x"),
                            capacity=64)
    s2 = bd.register_stream("streamstore0", "b.stream", ("t", "y"),
                            capacity=64)
    s1.append({"t": np.arange(8, dtype=float), "x": np.zeros(8)})
    s2.append({"t": np.arange(8, dtype=float) + 0.25, "y": np.ones(8)})
    r = bd.query("bdstream(join(window(a.stream, 8),"
                 " snapshot(b.stream), on=t, tol=0.3))")
    assert np.asarray(r.value.columns["dt"]).shape[0] == 8


# -- standing queries: watermark gating + late accounting ---------------------
def test_standing_join_ticks_only_on_watermark_advance():
    bd = default_deployment()
    a, b = _feed_pair(bd, np.random.default_rng(6), shards_a=2,
                      shards_b=2)
    cq = bd.register_continuous(JOIN_Q, name="j")
    snap = bd.register_continuous("bdstream(snapshot(j.abp))",
                                  name="plain_snap")
    bd.streams.tick()
    assert cq.executions == 1 and cq.event_time
    for _ in range(3):                     # watermark unchanged: skipped
        bd.streams.tick()
    assert cq.executions == 1 and cq.wm_skips == 3
    assert snap.executions == 4            # non-event-time: every tick
    a.append({"ts": [200.0], "abp": [1.0]})    # watermark advances
    b.append({"ts": [200.0], "ecg": [1.0]})
    bd.streams.tick()
    assert cq.executions == 2 and cq.wm_skips == 3
    m = bd.streams.status()["queries"]["j"]
    assert m["wm_skips"] == 3 and m["event_time"] is True


def test_standing_join_reruns_when_only_one_side_advances():
    """A join must re-execute when ANY referenced stream's watermark
    moves — one side's window can close while the other side stalls
    (gating on the min watermark would serve stale results)."""
    bd = default_deployment()
    a, b = _feed_pair(bd, np.random.default_rng(11), shards_a=1,
                      shards_b=1, rows=32)
    cq = bd.register_continuous(JOIN_Q, name="j")
    bd.streams.tick()
    assert cq.executions == 1
    stale = np.asarray(cq.last_value.columns["dt"]).shape[0]
    a.append({"ts": [200.0], "abp": [1.0]})    # only the LEFT advances
    bd.streams.tick()
    assert cq.executions == 2 and cq.wm_skips == 0
    # the left ewindow moved on to [168,192): the answer really changed
    assert np.asarray(cq.last_value.columns["dt"]).shape[0] != stale
    bd.streams.tick()                          # nothing advanced: skip
    assert cq.executions == 2 and cq.wm_skips == 1


def test_late_rows_charged_only_to_queries_reading_that_stream():
    bd = default_deployment()
    lossy = bd.register_stream("streamstore0", "lossy.ts", ("ts", "x"),
                               capacity=64, ts_field="ts", max_delay=0.0)
    bd.register_stream("streamstore0", "stable.ts", ("ts", "x"),
                       capacity=64, ts_field="ts", max_delay=0.0)
    on_lossy = bd.register_continuous(
        "bdstream(ewindow(lossy.ts, 4))", name="on_lossy")
    on_stable = bd.register_continuous(
        "bdstream(ewindow(stable.ts, 4))", name="on_stable")
    lossy.append({"ts": [10.0], "x": [1.0]})
    lossy.append({"ts": [3.0, 4.0], "x": [2.0, 3.0]})   # both late
    lossy.append({"ts": [15.0], "x": [4.0]})   # closes [8,12)
    bd.engines["streamstore0"].get("stable.ts").append(
        {"ts": [10.0, 15.0], "x": [0.0, 1.0]})
    bd.streams.tick()
    assert on_lossy.late_seen == 2
    assert on_stable.late_seen == 0
    assert bd.monitor.stream_stats["on_lossy"]["late"] == 2


def test_watermark_surfaced_in_monitor_and_status():
    bd = default_deployment()
    s = bd.register_stream("streamstore0", "wm.ts", ("ts", "x"),
                           capacity=64, ts_field="ts", max_delay=2.0)
    s.append({"ts": [0.0, 7.0], "x": [0.0, 1.0]})
    bd.streams.tick()
    st = admin.status(bd)
    info = st["streams"]["streams"]["wm.ts"]
    assert info["watermark"] == 5.0 and info["ts_field"] == "ts"
    assert info["pending"] == 1 and info["late"] == 0
    assert st["streams"]["watermarks"]["wm.ts"]["watermark"] == 5.0
    r = bd.query("bdstream(watermark(wm.ts))")
    assert float(r.value.columns["watermark"][0]) == 5.0
    # flush through BQL (punctuation as an island op)
    bd.query("bdstream(flush(wm.ts))")
    assert s.watermark == 7.0
    with pytest.raises(Exception):
        bd.query("bdstream(watermark(nope.ts))")


# -- planner ------------------------------------------------------------------
def test_planner_pins_join_reads_to_both_home_engines():
    bd = default_deployment()
    a = bd.register_stream("streamstore0", "p.a", ("ts", "x"),
                           capacity=256, shards=4, num_engines=4,
                           ts_field="ts", max_delay=0.0)
    b = bd.register_stream("streamstore0", "p.b", ("ts", "y"),
                           capacity=256, shards=4, num_engines=4,
                           ts_field="ts", max_delay=0.0)
    ts = np.arange(32, dtype=float)
    a.append({"ts": ts, "x": ts})
    b.append({"ts": ts, "y": ts})
    bd.rebalance_stream("p.b", shard=0, to_engine="streamstore1")
    assert a.home_engine == "streamstore0"
    assert b.home_engine == "streamstore1"
    q = ("bdstream(join(ewindow(p.a, 8), ewindow(p.b, 8),"
         " on=ts, tol=0.5))")
    plans = bd.planner.enumerate_plans(bql.parse(q))
    placed = {e for p in plans for e in p.node_engines.values()}
    assert placed == {"streamstore0", "streamstore1"}
    assert len(plans) == 2                 # not one per StreamEngine
    r = bd.query(q)                        # and the pinned plan runs
    assert np.asarray(r.value.columns["dt"]).shape[0] > 0


# -- live state & feeds -------------------------------------------------------
def test_export_state_roundtrip_preserves_event_time_state():
    s = Stream("m", ("ts", "x"), capacity=16, ts_field="ts",
               max_delay=10.0)
    s.append({"ts": [1.0, 8.0], "x": [1.0, 2.0]})       # all pending
    s.append({"ts": [0.5], "x": [3.0]})
    assert s._pending_rows == 3
    clone = Stream.from_state(s.export_state())
    assert clone.ts_field == "ts" and clone.max_delay == 10.0
    assert clone._pending_rows == 3 and clone.total_late == 0
    out = clone.flush()
    assert out["flushed"] == 3
    np.testing.assert_array_equal(
        np.asarray(clone.snapshot().columns["ts"]), [0.5, 1, 8])


def test_paired_mimic_feed_runs_standing_join_without_late_rows():
    from repro.data.mimic import stream_mimic_paired_waveforms
    bd = default_deployment()
    cq = bd.register_continuous(
        "bdstream(join(ewindow(mimic2v26.abp_stream, 16),"
        " ewindow(mimic2v26.ecg_stream, 16), on=ts, tol=0.5))",
        name="abp_ecg")
    infos = list(stream_mimic_paired_waveforms(
        bd, batch_rows=32, num_batches=8, jitter=2.0, max_delay=6.0))
    assert len(infos) == 9                 # 8 batches + final punctuation
    last = infos[-1]
    assert all(v == 0 for v in last["late"].values())   # bounded jitter
    assert cq.executions >= 2 and cq.errors == 0
    assert cq.cache_hits >= cq.executions - 1
    joined = cq.last_value
    assert np.asarray(joined.columns["dt"]).shape[0] > 0
    # the two jittered feeds reconstructed the exact in-order signal
    abp = bd.engines["streamstore0"].get("mimic2v26.abp_stream")
    snap = abp.snapshot()
    np.testing.assert_array_equal(np.asarray(snap.columns["ts"]),
                                  np.arange(8 * 32, dtype=float))


# -- idle-shard watermark timeout ---------------------------------------------
def _fake_clock(*streams):
    """Replace the streams' monotonic clock with a controllable one."""
    state = {"t": 1000.0}

    def now():
        return state["t"]

    for s in streams:
        s._now = now
    return state


def test_idle_shard_stalls_then_advances_after_timeout():
    """The ROADMAP idle-timeout: one quiet key range stalls the
    min-watermark (windows stay open) until ``idle_timeout`` elapses —
    then the idle shard is excluded and the watermark jumps without a
    manual flush()."""
    bd = default_deployment()
    sh = bd.register_stream(
        "streamstore0", "idle.stream", ("ts", "k"), capacity=1024,
        shards=2, num_engines=2, shard_key="k",
        ts_field="ts", max_delay=1.0, idle_timeout=5.0)
    clock = _fake_clock(sh)
    # both key ranges feed: k=0 -> shard 0, k=1 -> shard 1
    sh.append({"ts": [0.0, 1.0], "k": [0.0, 1.0]})
    sh.append({"ts": [2.0, 2.5], "k": [0.0, 1.0]})
    wm0 = sh.watermark
    assert wm0 == 1.0                       # min(2.0, 2.5) - 1.0
    # now only shard 0's range keeps feeding: the stream min stalls at
    # shard 1's last timestamp however far shard 0 advances
    for step in range(3):
        clock["t"] += 1.0
        sh.append({"ts": [10.0 + step], "k": [0.0]})
    assert sh.watermark == 1.5, "quiet shard should stall the min"
    # cross the idle threshold: the next arrival recomputes the basis
    # with shard 1 excluded and the watermark jumps to shard 0's frontier
    clock["t"] += 10.0
    sh.append({"ts": [13.0], "k": [0.0]})
    assert sh.watermark == 12.0             # 13.0 - max_delay
    # shard 1's range coming back re-enters the min (no longer idle);
    # below-watermark rows on it are late now — the punctuation cost
    out = sh.append({"ts": [5.0], "k": [1.0]})
    assert out["late"] == 1
    assert sh.watermark == 12.0
    sh.close()


def test_idle_advance_via_runtime_tick_without_any_arrivals():
    """A stream whose feeds ALL stop still advances: StreamRuntime.tick
    drives advance_idle_watermark(), so buffered rows flush and a
    watermark-gated standing query unsticks with no manual flush()."""
    bd = default_deployment()
    sh = bd.register_stream(
        "streamstore0", "idle.tick", ("ts", "k"), capacity=1024,
        shards=2, num_engines=2, shard_key="k",
        ts_field="ts", max_delay=2.0, idle_timeout=5.0)
    clock = _fake_clock(sh)
    cq = bd.register_continuous(
        "bdstream(aggregate(ewindow(idle.tick, 2), count(ts)))",
        name="idle_count")
    sh.append({"ts": [0.0, 1.0, 2.0, 3.0], "k": [0.0, 1.0, 0.0, 1.0]})
    bd.streams.tick()
    # watermark = min(shard maxes 2.0, 3.0) - max_delay = 0.0: the first
    # [0, 4) window is open, the standing query errors (no closed
    # ewindow yet) but the tick carries on
    assert sh.watermark == 0.0
    assert cq.executions + cq.errors >= 1
    # feeds stop; before the timeout a tick changes nothing...
    clock["t"] += 2.0
    bd.streams.tick()
    assert sh.watermark == 0.0 and sh._pending_rows > 0
    # ...after it, the tick itself flushes the stream out
    clock["t"] += 10.0
    ran = bd.streams.tick()
    assert sh.watermark == 3.0 and sh._pending_rows == 0
    # the SAME tick that advanced the idle watermark ran the gated
    # query successfully: ewindow [0, 2) is closed now and holds ts 0, 1
    assert [name for name, _ in ran] == ["idle_count"]
    assert float(np.asarray(
        cq.last_value.attrs["count_ts"])[0]) == 2.0
    sh.close()


def test_unsharded_idle_timeout_flushes_buffered_tail():
    """idle_timeout on a plain stream: after T seconds of silence the
    insertion buffer flushes in full (the automatic flush())."""
    s = Stream("idle.plain", ("ts",), capacity=64,
               ts_field="ts", max_delay=5.0, idle_timeout=3.0)
    clock = _fake_clock(s)
    s.append({"ts": [1.0, 4.0, 2.0]})
    assert s._pending_rows == 3             # watermark -1.0, nothing out
    clock["t"] += 1.0
    assert s.advance_idle_watermark()["flushed"] == 0   # not idle yet
    clock["t"] += 5.0
    out = s.advance_idle_watermark()
    assert out["flushed"] == 3 and s._pending_rows == 0
    assert s.watermark == 4.0
    np.testing.assert_array_equal(
        np.asarray(s.snapshot().columns["ts"]), [1.0, 2.0, 4.0])


def test_idle_timeout_ignored_without_event_time_axis():
    s = Stream("idle.plainest", ("v",), capacity=8, idle_timeout=1.0)
    s.append({"v": [1.0]})
    assert s.advance_idle_watermark() == {"flushed": 0, "dropped": 0}
