"""ml-island tests: ``bdml(infer(...))`` scores stream windows through
the model registry and the result is **bitwise** a direct
``registry.forward`` on the same rows — plain, sliding, sharded,
event-time and replayed-after-recovery streams all included — plus the
wave scheduler's one-wave-per-tick accounting, front-door scored
subscriptions ≡ direct standing queries, the jax-absent fallback, and
the admin/Monitor surface.  The CI jit-parity lane re-runs this file
under both REPRO_QUERY_BACKEND values: the inner window gather rides
the compiled stream path, so everything here must hold on both.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admin
from repro.core.api import default_deployment
from repro.models import registry
from repro.sharding import logical as L
from repro.stream import ml
from repro.stream.spec import Durability, EventTime, Sharding, StreamSpec

ARCH = "qwen2-1.5b"          # the "lm" alias; smallest forward in the pool
W = 16


def direct_score(values, arch=ARCH, seed=0):
    """The reference the island must match bitwise: quantize the rows,
    run a plain eager ``registry.forward``, mean next-token NLL in f32."""
    cfg = registry.get_config(arch, reduced=True)
    params = L.init_params(jax.random.PRNGKey(seed),
                           registry.param_specs(cfg))
    toks = ml.quantize(np.asarray(values, np.float64), cfg.vocab_size)
    logits, _ = registry.forward(
        params, {"tokens": jnp.asarray(toks[None, :], jnp.int32)}, cfg,
        None)
    logp = jax.nn.log_softmax(logits[0, :-1].astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, jnp.asarray(toks[1:, None]),
                               -1)[..., 0]
    return nll.mean()


def _deploy(spec=None):
    bd = default_deployment()
    bd.register_model("lm")
    if spec is not None:
        bd.register_stream("streamstore0", spec)
    return bd


def _rows(n=W, seed=0):
    rng = np.random.default_rng(seed)
    return {"ts": np.arange(float(n)),
            "hr": 70 + 8 * np.sin(np.arange(n) / 3)
            + rng.standard_normal(n)}


# -- bit-identity: infer ≡ direct registry.forward ---------------------------
def test_infer_matches_direct_forward_bitwise():
    bd = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    rows = _rows()
    bd.engines["streamstore0"].get("vitals.hr").append(rows)
    out = bd.query(f"bdml(infer(window(vitals.hr, {W}), models.lm))").value
    assert out.columns["score"].dtype == jnp.float32
    assert int(out.columns["rows"][0]) == W
    want = direct_score(rows["hr"])
    err = float(jnp.abs(out.columns["score"][0] - want))
    assert err == 0.0, f"infer vs direct forward: {err:.3e}"


def test_infer_sliding_windows_each_match_direct():
    bd = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    rows = _rows(2 * W)
    bd.engines["streamstore0"].get("vitals.hr").append(rows)
    out = bd.query(
        f"bdml(infer(window(vitals.hr, {W}, {W}), models.lm))").value
    n = int(out.columns["window"].shape[0])
    assert n == 2
    for i in range(n):
        want = direct_score(rows["hr"][i * W:(i + 1) * W])
        err = float(jnp.abs(out.columns["score"][i] - want))
        assert err == 0.0, f"window {i}: {err:.3e}"


def test_infer_field_kwarg_and_defaults():
    bd = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    rows = _rows()
    bd.engines["streamstore0"].get("vitals.hr").append(rows)
    q = f"bdml(infer(window(vitals.hr, {W}), models.lm, field=%s))"
    explicit = bd.query(q % "hr").value
    default = bd.query(
        f"bdml(infer(window(vitals.hr, {W}), models.lm))").value
    # the default field skips the ts column and picks hr
    assert float(explicit.columns["score"][0]) == \
        float(default.columns["score"][0])
    ts_scored = bd.query(q % "ts").value
    want = direct_score(rows["ts"])
    assert float(jnp.abs(ts_scored.columns["score"][0] - want)) == 0.0


def test_sharded_scores_match_unsharded_bitwise():
    rows = _rows(2 * W, seed=3)
    plain = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    plain.engines["streamstore0"].get("vitals.hr").append(rows)
    sharded = _deploy(StreamSpec(
        "vitals.hr", ("ts", "hr"), capacity=64,
        sharding=Sharding(shards=2, num_engines=2)))
    sharded.engines["streamstore0"].get("vitals.hr").append(rows)
    q = f"bdml(infer(window(vitals.hr, {W}, {W}), models.lm))"
    a = plain.query(q).value
    b = sharded.query(q).value
    np.testing.assert_array_equal(np.asarray(a.columns["score"]),
                                  np.asarray(b.columns["score"]))


def test_event_time_window_scores_match_direct():
    bd = _deploy(StreamSpec(
        "icu.abp", ("ts", "abp"), capacity=128,
        event_time=EventTime("ts", max_delay=4.0)))
    s = bd.engines["streamstore0"].get("icu.abp")
    rng = np.random.default_rng(7)
    ts = np.arange(24.0)
    order = np.argsort(ts + rng.uniform(-2, 2, ts.shape[0]))
    s.append({"ts": ts[order], "abp": (80 + ts)[order]})
    s.flush()                              # close every window
    view = bd.query("bdstream(ewindow(icu.abp, 16.0))").value
    out = bd.query(
        "bdml(infer(ewindow(icu.abp, 16.0), models.lm))").value
    want = direct_score(np.asarray(view.attrs["abp"], np.float64))
    err = float(jnp.abs(out.columns["score"][0] - want))
    assert err == 0.0, f"event-time infer vs direct: {err:.3e}"
    # gathered window is event-time ordered regardless of arrival order
    np.testing.assert_array_equal(
        np.sort(np.asarray(view.attrs["ts"])), np.asarray(view.attrs["ts"]))


def test_replayed_durable_stream_scores_identically(tmp_path):
    spec = StreamSpec("vitals.hr", ("ts", "hr"), capacity=64,
                      durability=Durability(str(tmp_path / "wal"),
                                            checkpoint_every_rows=8))
    bd = _deploy(spec)
    stream = bd.engines["streamstore0"].get("vitals.hr")
    stream.append(_rows(seed=11))
    q = f"bdml(infer(window(vitals.hr, {W}), models.lm))"
    before = bd.query(q).value
    stream._durable.close()
    bd2 = default_deployment()             # the "restart"
    bd2.recover_stream("streamstore0", str(tmp_path / "wal"))
    bd2.register_model("lm")
    after = bd2.query(q).value
    np.testing.assert_array_equal(np.asarray(before.columns["score"]),
                                  np.asarray(after.columns["score"]))


# -- wave scheduling ----------------------------------------------------------
def test_standing_infer_queries_share_one_wave_per_tick():
    bd = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    bd.engines["streamstore0"].get("vitals.hr").append(_rows())
    n = 3
    for i in range(n):
        bd.register_continuous(
            f"bdml(infer(window(vitals.hr, {W}), models.lm))"
            if i == 0 else
            f"bdml(infer(window(vitals.hr, {W}), models.lm, field=hr))",
            name=f"scored{i}")
    s0 = ml.stats()
    ran = bd.streams.tick()
    s1 = ml.stats()
    assert len(ran) == n
    assert s1["waves"] - s0["waves"] == 1
    assert s1["wave_submissions"] - s0["wave_submissions"] == n
    assert s1["infer_executions"] - s0["infer_executions"] == n
    bd.streams.tick()
    s2 = ml.stats()
    assert s2["waves"] - s1["waves"] == 1


def test_params_cache_shared_across_queries():
    bd = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    bd.engines["streamstore0"].get("vitals.hr").append(_rows())
    q = f"bdml(infer(window(vitals.hr, {W}), models.lm))"
    s0 = ml.stats()
    bd.query(q)
    bd.query(q)
    s1 = ml.stats()
    # the (arch, seed) entry was loaded at most once this test; the
    # second execution is always a cache hit
    assert s1["params_cache_hits"] - s0["params_cache_hits"] >= 1
    assert ("qwen2-1.5b", 0) in ml._LOADED


# -- front door ---------------------------------------------------------------
def test_frontdoor_scored_subscription_matches_direct():
    from repro.serve.engine import ServeConfig
    from repro.serve.frontdoor import FrontDoor
    bd = _deploy()
    door = FrontDoor(bd, ServeConfig(streams=(
        StreamSpec("vitals.hr", ("ts", "hr"), capacity=64),)),
        stream_engine="streamstore0")
    q = f"bdml(infer(window(vitals.hr, {W}), models.lm))"
    sub_a = door.open_session("a").subscribe(q)
    sub_b = door.open_session("b").subscribe(q)
    direct = bd.register_continuous(q, name="direct")
    bd.engines["streamstore0"].get("vitals.hr").append(_rows(seed=5))
    bd.streams.tick()
    got_a, got_b = sub_a.poll(), sub_b.poll()
    assert len(got_a) == 1 and len(got_b) == 1
    sa = np.asarray(got_a[0][1].columns["score"])
    sb = np.asarray(got_b[0][1].columns["score"])
    sd = np.asarray(direct.last_value.columns["score"])
    np.testing.assert_array_equal(sa, sd)
    np.testing.assert_array_equal(sb, sd)
    # warm sharing: both tenants rode ONE shared standing query
    assert door.stats()["shared_queries"] == 1
    door.close()


# -- failure modes ------------------------------------------------------------
def test_incomplete_window_is_transient():
    from repro.core.executor import (DataUnavailableException,
                                     LocalQueryExecutionException)
    bd = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    bd.engines["streamstore0"].get("vitals.hr").append(_rows(n=4))
    with pytest.raises(LocalQueryExecutionException) as exc:
        bd.query(f"bdml(infer(window(vitals.hr, {W}), models.lm))")
    # the cause chain carries the transient marker (plan-cache survival)
    assert isinstance(exc.value.__cause__, DataUnavailableException)
    # standing queries survive it: the error is isolated per tick
    cq = bd.register_continuous(
        f"bdml(infer(window(vitals.hr, {W}), models.lm))", name="scored")
    bd.streams.tick()
    assert cq.errors == 1 and cq.executions == 0


def test_jax_absent_is_graceful(monkeypatch):
    bd = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    bd.engines["streamstore0"].get("vitals.hr").append(_rows())
    cq = bd.register_continuous(
        f"bdml(infer(window(vitals.hr, {W}), models.lm))", name="scored")
    monkeypatch.setattr(ml, "JAX_AVAILABLE", False)
    s0 = ml.stats()
    with pytest.raises(Exception, match="jax"):
        bd.query(f"bdml(infer(window(vitals.hr, {W}), models.lm))")
    ran = bd.streams.tick()                # the tick itself survives
    assert ran == []
    assert cq.errors == 1 and "jax" in cq.last_error
    assert ml.stats()["fallbacks"] - s0["fallbacks"] == 2
    monkeypatch.setattr(ml, "JAX_AVAILABLE", True)
    bd.streams.tick()
    assert cq.executions == 1              # recovered on the next tick


def test_unknown_model_and_bad_args():
    bd = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    bd.engines["streamstore0"].get("vitals.hr").append(_rows())
    with pytest.raises(Exception, match="not registered"):
        bd.query(f"bdml(infer(window(vitals.hr, {W}), models.nope))")
    with pytest.raises(Exception, match="no field"):
        bd.query(f"bdml(infer(window(vitals.hr, {W}), models.lm,"
                 f" field=bogus))")
    with pytest.raises(ml.MLException, match="unknown model"):
        ml.resolve_arch("not-an-arch")
    assert ml.resolve_arch("moe") == "olmoe-1b-7b"
    assert ml.resolve_arch("qwen2-1.5b") == "qwen2-1.5b"


# -- surface ------------------------------------------------------------------
def test_admin_status_and_planner_pinning():
    bd = _deploy(StreamSpec("vitals.hr", ("ts", "hr"), capacity=64))
    bd.engines["streamstore0"].get("vitals.hr").append(_rows())
    resp = bd.query(f"bdml(infer(window(vitals.hr, {W}), models.lm))")
    # the ml branch pins the read to the model's home engine: one plan
    assert resp.plans_considered == 1
    assert "mlhost0" in resp.qep_id
    bd.streams.tick()
    st = admin.status(bd)
    assert st["ml"]["jax_available"] is True
    for key in ("models_loaded", "waves", "windows_scored",
                "infer_executions", "fallbacks"):
        assert key in st["ml"], key
    assert "mlhost0" in st["islands"]["ml"]
    assert st["engines"]["mlhost0"]["kind"] == "mlserve"
