"""Property-based suite for the streaming island (hypothesis): random
interleavings of appends, out-of-order event-time rows, and flush
punctuation must preserve the core stream invariants however they are
sequenced —

  * gathered ``seq`` strictly increasing and gap-free,
  * the ring never exceeds its capacity,
  * ``total_dropped + retained == appended``,
  * the low watermark is monotone,
  * the rolling (cumulative-ring) sum equals a recomputed sum,
  * an unsharded stream and a sharded one fed the same operation
    sequence gather bit-identically.

These are the invariants the concurrent-producer path is "correct
because of" (tests/test_stream_concurrent_producers.py races them);
here hypothesis hunts the *sequential* edge cases: batches larger than
capacity, empty batches, flushes with nothing pending, ties in ts,
eviction straddling window boundaries.

Skips cleanly when hypothesis is not installed (CI installs the
``property`` extra; the container image may not have it)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.api import default_deployment  # noqa: E402
from repro.stream.engine import Stream  # noqa: E402

# one operation is ("append", row-values) or ("flush", to_ts | None);
# values double as both payload and (for event-time runs) jittered
# timestamps
_BATCH = st.lists(
    st.floats(min_value=0.0, max_value=400.0,
              allow_nan=False, allow_infinity=False),
    min_size=0, max_size=40)
_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("append"), _BATCH),
        st.tuples(st.just("flush"),
                  st.one_of(st.none(),
                            st.floats(min_value=0.0, max_value=500.0,
                                      allow_nan=False,
                                      allow_infinity=False)))),
    min_size=1, max_size=24)

_SETTINGS = settings(max_examples=40, deadline=None,
                     suppress_health_check=[HealthCheck.too_slow])


def _apply_plain(ops, capacity):
    """Feed one op sequence into a fresh append-ordered Stream,
    checking per-op invariants; returns the stream."""
    stream = Stream("prop.plain", ("v",), capacity=capacity)
    appended = 0
    for op, arg in ops:
        if op != "append":
            continue                     # flush: event-time runs only
        counts = stream.append({"v": np.asarray(arg, np.float64)})
        appended += len(arg)
        assert counts["appended"] == len(arg)
        assert stream.num_rows <= capacity
        assert stream.total_appended == appended
        assert stream.total_dropped + stream.num_rows == appended
    return stream


@given(ops=_OPS, capacity=st.integers(min_value=1, max_value=64))
@_SETTINGS
def test_plain_stream_invariants_hold_under_any_sequence(ops, capacity):
    stream = _apply_plain(ops, capacity)
    snap = stream.snapshot()
    seqs = np.asarray(snap.columns["seq"])
    if seqs.size:
        # strictly increasing, gap-free, ending at the high-water mark
        assert (np.diff(seqs) == 1).all()
        assert seqs[-1] == stream.total_appended - 1
        assert seqs.size == stream.num_rows


@given(ops=_OPS,
       capacity=st.integers(min_value=8, max_value=128),
       shards=st.integers(min_value=2, max_value=4),
       block_rows=st.integers(min_value=1, max_value=16),
       shard_key=st.booleans())
@_SETTINGS
def test_sharded_gather_bit_identical_to_unsharded(
        ops, capacity, shards, block_rows, shard_key):
    """The same append sequence through a plain Stream and through a
    ShardedStream gathers bit-identically while no shard ring has
    evicted (capacity is split per shard, so this run keeps totals
    under the smallest ring)."""
    total = sum(len(arg) for op, arg in ops if op == "append")
    per_shard = -(-capacity // shards)
    if total > per_shard:
        ops = ops[:1]  # trim: eviction asymmetry is covered elsewhere
        total = sum(len(arg) for op, arg in ops if op == "append")
        if total > per_shard:
            return
    plain = Stream("prop.ref", ("v",), capacity=capacity)
    bd = default_deployment()
    sharded = bd.register_stream(
        "streamstore0", "prop.sharded", ("v",), capacity=capacity,
        shards=shards, num_engines=2, block_rows=block_rows,
        shard_key="v" if shard_key else None)
    for op, arg in ops:
        if op != "append":
            continue
        batch = np.asarray(arg, np.float64)
        plain.append({"v": batch})
        sharded.append({"v": batch})
    ref = plain.snapshot()
    got = sharded.snapshot()
    np.testing.assert_array_equal(np.asarray(ref.columns["seq"]),
                                  np.asarray(got.columns["seq"]))
    np.testing.assert_array_equal(np.asarray(ref.columns["v"]),
                                  np.asarray(got.columns["v"]))
    assert sharded.total_appended == plain.total_appended
    sharded.close()


@given(ops=_OPS, max_delay=st.floats(min_value=0.0, max_value=50.0,
                                     allow_nan=False))
@_SETTINGS
def test_event_time_invariants_hold_under_any_interleaving(ops,
                                                           max_delay):
    """Out-of-order ingest + random flush punctuation: the watermark
    never regresses, the ring is ts-sorted, seqs stay gap-free, and
    appended == flushed + pending + late."""
    stream = Stream("prop.ev", ("v",), capacity=4096,
                    ts_field="v", max_delay=max_delay)
    sent = 0
    last_wm = float("-inf")
    for op, arg in ops:
        if op == "append":
            counts = stream.append({"v": np.asarray(arg, np.float64)})
            sent += len(arg)
            assert counts["appended"] + counts["late"] == len(arg)
        else:
            stream.flush(arg)
        assert stream.watermark >= last_wm, "watermark regressed"
        last_wm = stream.watermark
        assert (stream.total_appended + stream._pending_rows
                + stream.total_late == sent)
    stream.flush()
    snap = stream.snapshot()
    seqs = np.asarray(snap.columns["seq"])
    ts = np.asarray(snap.columns["v"])
    if seqs.size:
        assert (np.diff(seqs) == 1).all()
        assert (np.diff(ts) >= 0).all(), "ring not ts-sorted"
    # every row accounted for exactly once
    assert stream.total_appended + stream.total_late == sent
    assert stream._pending_rows == 0


@given(batches=st.lists(
    st.lists(st.floats(min_value=-100, max_value=100,
                       allow_nan=False, allow_infinity=False),
             min_size=1, max_size=30),
    min_size=2, max_size=12),
    size=st.integers(min_value=2, max_value=32))
@_SETTINGS
def test_rolling_sum_equals_recomputed_sum(batches, size):
    """The O(1) cumulative-ring window aggregate must equal a cold
    recompute over the materialized window, for any batch sequence that
    leaves the window un-evicted."""
    capacity = 4096
    stream = Stream("prop.roll", ("v",), capacity=capacity)
    for batch in batches:
        stream.append({"v": np.asarray(batch, np.float64)})
    if stream.total_appended < size:
        return
    rolling = stream.window_aggregate(size, "sum", "v")
    window = np.asarray(stream.window(size).attrs["v"], np.float64)
    assert rolling == pytest.approx(float(window.sum()), abs=1e-6)
    avg = stream.window_aggregate(size, "avg", "v")
    assert avg == pytest.approx(float(window.mean()), abs=1e-6)


@given(ops=_OPS, capacity=st.integers(min_value=4, max_value=32),
       shards=st.integers(min_value=2, max_value=3))
@_SETTINGS
def test_sharded_drop_accounting_under_eviction(ops, capacity, shards):
    """Even once shard rings evict, appended == dropped + retained and
    the gathered seqs stay strictly increasing (gaps allowed: shard
    rings evict independently by design)."""
    bd = default_deployment()
    sharded = bd.register_stream(
        "streamstore0", "prop.evict", ("v",), capacity=capacity,
        shards=shards, num_engines=2, block_rows=2)
    appended = 0
    for op, arg in ops:
        if op != "append":
            continue
        sharded.append({"v": np.asarray(arg, np.float64)})
        appended += len(arg)
        assert sharded.total_appended == appended
        assert sharded.total_dropped + sharded.num_rows == appended
    seqs = np.asarray(sharded.snapshot().columns["seq"])
    if seqs.size:
        assert (np.diff(seqs) > 0).all()
        assert seqs[-1] <= sharded.total_appended - 1
    sharded.close()


# -- compiled-backend bit-identity (jit ≡ interpreted) ------------------------
# full-precision float64 payloads: the compiled path stores the ring as
# f64, computes under a scoped x64, and casts outputs to the ambient
# default dtype — any round-trip loss or reassociation shows up here as
# a bitwise mismatch
_PRECISE = st.lists(
    st.floats(min_value=-1e9, max_value=1e9,
              allow_nan=False, allow_infinity=False),
    min_size=1, max_size=60)


def _run_backend(bd, query, backend):
    """One query under one backend -> ("ok", value) | ("err", str)."""
    from repro.stream import compile as qc
    import os
    prev = os.environ.get(qc.BACKEND_ENV)
    os.environ[qc.BACKEND_ENV] = backend
    try:
        return "ok", bd.query(f"bdstream({query})").value
    except Exception as exc:                  # noqa: BLE001 — compared
        return "err", str(exc)
    finally:
        if prev is None:
            os.environ.pop(qc.BACKEND_ENV, None)
        else:
            os.environ[qc.BACKEND_ENV] = prev


def _assert_backend_parity(bd, query):
    """jit must be *bit-identical* to interpreted: same values, dtypes,
    column order — or the exact same error string."""
    ref_kind, ref = _run_backend(bd, query, "interpreter")
    got_kind, got = _run_backend(bd, query, "jit")
    assert ref_kind == got_kind, (query, ref, got)
    if ref_kind == "err":
        assert ref == got, query
        return
    r_cols = dict(getattr(ref, "columns", None) or ref.attrs)
    g_cols = dict(getattr(got, "columns", None) or got.attrs)
    assert list(r_cols) == list(g_cols), query
    for k in r_cols:
        rv, gv = np.asarray(r_cols[k]), np.asarray(g_cols[k])
        assert rv.dtype == gv.dtype, (query, k)
        np.testing.assert_array_equal(rv, gv, err_msg=f"{query} [{k}]")


@pytest.mark.parametrize("query", [
    "window(pb.s, 8)",
    "window(pb.s, 8, 3)",
    "aggregate(window(pb.s, 8), sum(v))",
    "aggregate(window(pb.s, 8), avg(v))",
    "aggregate(window(pb.s, 8), max(v))",
    "aggregate(window(pb.s, 8, 3), min(v))",
])
@given(vals=_PRECISE)
@_SETTINGS
def test_jit_backend_bit_identical_on_windows(query, vals):
    """hypothesis drives the payloads; every compiled window/aggregate
    shape must match the interpreter bit-for-bit (including the
    not-enough-rows error strings)."""
    pytest.importorskip("jax")
    bd = default_deployment()
    s = bd.register_stream("streamstore0", "pb.s", ("v",), capacity=128)
    s.append({"v": np.asarray(vals, np.float64)})
    _assert_backend_parity(bd, query)


@given(ts=st.lists(st.floats(min_value=0.0, max_value=100.0,
                             allow_nan=False, allow_infinity=False),
                   min_size=2, max_size=50),
       tol=st.floats(min_value=0.01, max_value=5.0, allow_nan=False))
@_SETTINGS
def test_jit_join_bit_identical_under_random_event_times(ts, tol):
    """The compiled banded interval join against the interpreter, over
    arbitrary (tied, duplicated, clustered) event times — match pairs,
    ordering and the dt column must agree exactly."""
    pytest.importorskip("jax")
    bd = default_deployment()
    a = bd.register_stream("streamstore0", "pb.a", ("ts", "x"),
                           capacity=256, ts_field="ts", max_delay=0.0)
    b = bd.register_stream("streamstore0", "pb.b", ("ts", "y"),
                           capacity=256, ts_field="ts", max_delay=0.0)
    arr = np.asarray(ts, np.float64)
    a.append({"ts": arr, "x": np.arange(arr.size, dtype=np.float64)})
    b.append({"ts": arr + 0.125, "y": -np.arange(arr.size,
                                                 dtype=np.float64)})
    a.flush()
    b.flush()
    q = (f"join(ewindow(pb.a, 25, 10), ewindow(pb.b, 25, 10),"
         f" on=ts, tol={tol!r})")
    _assert_backend_parity(bd, q)
