"""Sharded streaming scale-out tests (arXiv:1609.07548 §streams across
engines): scatter/gather round-trips bit-identical to the single-shard
stream, rolling window aggregates via per-shard partials, live shard
migration (Migrator ``stream`` route) preserving seq/drop accounting
mid-standing-query, the Monitor-driven rebalance hook, and the opt-in
background tick driver."""
import threading
import time

import numpy as np
import pytest

from repro.core import admin
from repro.core.api import default_deployment
from repro.core.migrator import MigrationException, MigrationParams
from repro.core.monitor import Monitor
from repro.stream.engine import ShardedStream, Stream, StreamEngine


def _mk_pair(shards=3, capacity=96, fields=("x", "y"), shard_key=None,
             block_rows=4):
    """(unsharded reference Stream, equivalent ShardedStream)."""
    ref = Stream("s", fields, capacity)
    engines = [StreamEngine(f"streamstore{i}") for i in range(shards)]
    parts = [(e.name, e.create_stream(f"s@shard{i}",
                                      tuple(fields) + ("__seq",),
                                      -(-capacity // shards)))
             for i, e in enumerate(engines)]
    return ref, ShardedStream("s", fields, parts, shard_key=shard_key,
                              block_rows=block_rows)


# -- scatter/gather equals the single-shard result ----------------------------
@pytest.mark.parametrize("shard_key", [None, "x"])
def test_gather_bit_identical_to_unsharded(shard_key):
    ref, sh = _mk_pair(shards=3, shard_key=shard_key)
    rng = np.random.default_rng(0)
    for _ in range(6):
        batch = {"x": rng.integers(0, 9, 13).astype(float),
                 "y": rng.standard_normal(13)}
        ref.append(batch)
        sh.append(batch)
    for view in (lambda s: s.snapshot().columns["y"],
                 lambda s: s.snapshot().columns["seq"],
                 lambda s: s.window(32).attrs["y"],        # tumbling
                 lambda s: s.window(16, 8).attrs["y"]):    # sliding
        np.testing.assert_array_equal(np.asarray(view(ref)),
                                      np.asarray(view(sh)))
    assert ref.total_appended == sh.total_appended == 78


@pytest.mark.parametrize("fn", ["count", "sum", "avg", "min", "max"])
def test_window_aggregate_partials_match_unsharded(fn):
    ref, sh = _mk_pair(shards=3)
    rng = np.random.default_rng(1)
    raw = []
    for _ in range(5):
        batch = {"x": rng.standard_normal(16),
                 "y": rng.standard_normal(16)}
        raw.append(batch["y"])
        ref.append(batch)
        sh.append(batch)
    win = np.concatenate(raw)[32:64]           # latest complete 32-window
    direct = {"count": float(len(win)), "sum": win.sum(),
              "avg": win.mean(), "min": win.min(), "max": win.max()}[fn]
    assert ref.window_aggregate(32, fn, "y") == pytest.approx(direct)
    assert sh.window_aggregate(32, fn, "y") == pytest.approx(direct)
    # repeat ticks over the same window are memoized (the rolling path)
    before = sh.agg_computes
    sh.window_aggregate(32, fn, "y")
    assert sh.agg_computes == before and sh.agg_cache_hits == 1


def test_sharded_ops_via_bql_are_shard_transparent():
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "vitals.stream", ("hr",),
                            capacity=512, shards=4, num_engines=2,
                            block_rows=4)
    assert sorted(e for e in bd.engines if e.startswith("streamstore")) \
        == ["streamstore0", "streamstore1"]
    sh.append({"hr": np.arange(64, dtype=float)})
    snap = bd.query("bdstream(snapshot(vitals.stream))").value
    np.testing.assert_array_equal(np.asarray(snap.columns["hr"]),
                                  np.arange(64))
    agg = bd.query("bdstream(aggregate(window(vitals.stream, 32),"
                   " avg(hr)))").value
    assert float(agg.attrs["avg_hr"][0]) == pytest.approx(47.5)
    # gathered window casts into the array island like any window view
    r = bd.query("bdarray(aggregate(bdcast(bdstream(window("
                 "vitals.stream, 32)), w_arr,"
                 " '<hr:double>[tick=0:31,32,0]', array), max(hr)))")
    assert float(r.value.attrs["max_hr"][0]) == 63.0
    # the handle lives on every participating engine; plans pin to home
    assert bd.engines["streamstore1"].get("vitals.stream") is sh
    assert sh.home_engine == "streamstore0"


def test_sharded_drop_accounting_sums_shards():
    _, sh = _mk_pair(shards=2, capacity=16, block_rows=2)
    sh.append({"x": np.arange(40, dtype=float),
               "y": np.arange(40, dtype=float)})
    assert sh.total_appended == 40
    assert sh.total_dropped == 40 - sh.num_rows > 0
    stats = sh.stats()
    assert stats["dropped"] == sum(s["dropped"]
                                   for s in stats["shards"].values())


# -- live shard migration -----------------------------------------------------
def test_stream_route_moves_live_state():
    bd = default_deployment(stream_engines=2)
    src = bd.engines["streamstore0"]
    dst = bd.engines["streamstore1"]
    stream = bd.register_stream("streamstore0", "solo.stream", ("x",),
                                capacity=8)
    stream.append({"x": np.arange(20, dtype=float)})   # 12 dropped
    result = bd.migrator.migrate(src, "solo.stream", dst, "solo.stream",
                                 MigrationParams(method="stream"))
    assert result.method == "stream" and result.rows == 8
    assert not src.has("solo.stream")                  # moved, not copied
    moved = dst.get("solo.stream")
    assert moved.total_appended == 20 and moved.total_dropped == 12
    np.testing.assert_array_equal(
        np.asarray(moved.snapshot().columns["seq"]), np.arange(12, 20))
    moved.append({"x": [99.0]})                        # watermark continues
    assert moved.total_appended == 21
    # rolling state travelled too: O(1) range sums still correct
    assert moved.range_sum("x", 0, 8) == pytest.approx(
        np.arange(13, 21).sum() + 99 - 20)


def test_stream_route_rejects_non_streams():
    bd = default_deployment(stream_engines=2)
    bd.engines["streamstore0"].put("not_a_stream", np.arange(3))
    with pytest.raises(MigrationException):
        bd.migrator.migrate(bd.engines["streamstore0"], "not_a_stream",
                            bd.engines["streamstore1"], "x",
                            MigrationParams(method="stream"))
    stream = bd.register_stream("streamstore0", "s2", ("x",), capacity=8)
    stream.append({"x": [1.0]})
    with pytest.raises(MigrationException):
        bd.migrator.migrate(bd.engines["streamstore0"], "s2",
                            bd.engines["hoststore0"], "s2",
                            MigrationParams(method="stream"))


def test_live_migration_preserves_standing_query_continuity():
    """Move a shard between StreamEngines mid-standing-query: seq/drop
    accounting is preserved and the query's next tick both executes and
    still hits the plan cache (the logical placement didn't change)."""
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "vitals.stream", ("hr",),
                            capacity=256, shards=4, num_engines=2,
                            block_rows=8)
    cq = bd.register_continuous(
        "bdstream(aggregate(window(vitals.stream, 32), avg(hr)))",
        name="hr_avg")
    rng = np.random.default_rng(2)
    sh.append({"hr": rng.standard_normal(48)})
    bd.streams.tick()
    assert cq.executions == 1 and cq.errors == 0
    appended, dropped = sh.total_appended, sh.total_dropped
    move = bd.rebalance_stream("vitals.stream", shard=0,
                               to_engine="streamstore1")
    assert move["from"] == "streamstore0" and move["to"] == "streamstore1"
    assert sh.total_appended == appended and sh.total_dropped == dropped
    assert sh.shard_engines()[0] == "streamstore1"
    # the catalog followed the shard
    assert bd.catalog.engine_for_object(
        "vitals.stream@shard0").name == "streamstore1"
    sh.append({"hr": rng.standard_normal(48)})
    bd.streams.tick()
    assert cq.executions == 2 and cq.errors == 0
    assert cq.cache_hits >= 1                    # plan survived the move
    assert bd.streams.status()["rebalances"][0]["shard"] == 0


def test_rebalance_hook_moves_shard_off_lopsided_engine():
    """Skewed shard-key traffic makes the Monitor's per-shard stats
    lopsided; admin.rebalance() then moves a shard off the hot engine."""
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "skew.stream",
                            ("patient", "hr"), capacity=2048, shards=4,
                            shard_key="patient", num_engines=2)
    rng = np.random.default_rng(3)
    for _ in range(6):
        # patient ids hash (floor(|v|) % 4) onto mostly shard 1
        patient = np.where(rng.random(128) < 0.9, 1.0,
                           rng.integers(0, 4, 128).astype(float))
        sh.append({"patient": patient,
                   "hr": 75 + rng.standard_normal(128)})
        bd.streams.tick()
    assert bd.monitor.lopsided_shards("skew.stream") == [1]
    outcome = admin.rebalance(bd)
    assert len(outcome["moves"]) == 1 and not outcome["skipped"]
    move = outcome["moves"][0]
    assert move["stream"] == "skew.stream"
    # load is evener now: the two engines no longer share the hot shard
    engines = [s["engine"] for s in sh.shard_stats().values()]
    hot_engine = sh.shard_stats()[1]["engine"]
    assert engines.count(hot_engine) < 3
    # no further move helps (the hot shard dominates on its own engine):
    # the hook reports the stream as skipped rather than thrashing shards
    again = admin.rebalance(bd)
    assert again["moves"] == []
    assert [s["stream"] for s in again["skipped"]] == ["skew.stream"]


def test_lopsided_detection_works_with_two_shards():
    """With the upper median a 2-shard stream could never look lopsided
    (the hot shard IS the median); the lower median flags it."""
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "duo.stream", ("k", "v"),
                            capacity=1024, shards=2, shard_key="k",
                            num_engines=2)
    rng = np.random.default_rng(6)
    # every key is odd -> everything hashes onto shard 1
    sh.append({"k": np.ones(256), "v": rng.standard_normal(256)})
    bd.streams.tick()
    assert bd.monitor.lopsided_shards("duo.stream") == [1]


def test_rebalance_refuses_useless_moves():
    bd = default_deployment()
    bd.register_stream("streamstore0", "flat.stream", ("x",),
                       capacity=512, shards=2, num_engines=2,
                       block_rows=2)
    sh = bd.engines["streamstore0"].get("flat.stream")
    sh.append({"x": np.arange(64, dtype=float)})
    with pytest.raises(ValueError):
        bd.streams.rebalance("flat.stream")      # 1 shard/engine: no gain
    with pytest.raises(ValueError):
        bd.streams.rebalance("nonexistent.stream")
    with pytest.raises(ValueError):              # bad explicit shard
        bd.streams.rebalance("flat.stream", shard=9,
                             to_engine="streamstore1")
    with pytest.raises(ValueError):              # bad explicit engine
        bd.streams.rebalance("flat.stream", shard=0,
                             to_engine="streamstoreX")


def test_sharded_stream_resolves_on_anchor_engine():
    """The caller-named engine must hold the handle even when the shards
    spread over streamstore0..N-1 (stream_mimic_waveforms resolves the
    stream through the anchor engine)."""
    from repro.data.mimic import stream_mimic_waveforms
    bd = default_deployment(stream_engines=3)
    sh = bd.register_stream("streamstore2", "anchored.stream", ("x",),
                            capacity=256, shards=2, num_engines=2)
    assert bd.engines["streamstore2"].get("anchored.stream") is sh
    assert sh.shard_engines() == ["streamstore0", "streamstore1"]
    bd2 = default_deployment(stream_engines=3)
    ran = list(stream_mimic_waveforms(bd2, batch_rows=16, num_batches=2,
                                      engine_name="streamstore2",
                                      shards=2))
    assert len(ran) == 2 and ran[-1]["rows"] == 32


def test_stream_route_refuses_self_move():
    bd = default_deployment()
    stream = bd.register_stream("streamstore0", "self.stream", ("x",),
                                capacity=8)
    stream.append({"x": [1.0, 2.0]})
    eng = bd.engines["streamstore0"]
    with pytest.raises(MigrationException):
        bd.migrator.migrate(eng, "self.stream", eng, "self.stream",
                            MigrationParams(method="stream"))
    assert eng.has("self.stream")            # buffer untouched


def test_rebalance_finds_moves_beyond_busiest_engine():
    """Loads A=hot(unmovable alone), B=two light shards, C=idle: the
    improving move donates a light shard from B to C even though B is
    not the busiest engine."""
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "tri.stream", ("k", "v"),
                            capacity=4096, shards=3, shard_key="k",
                            num_engines=3)
    # key m hashes to shard m % 3; shards land on engines 0,1,2 — pile
    # weight on shard 0 (engine A) and split light load on shards 1,2...
    # then co-locate shards 1 and 2 by moving shard 2 onto engine 1
    bd.streams.rebalance("tri.stream", shard=2, to_engine="streamstore1")
    rng = np.random.default_rng(5)
    k = np.concatenate([np.zeros(600), np.ones(90),
                        np.full(90, 2.0)])
    sh.append({"k": k, "v": rng.standard_normal(len(k))})
    bd.streams.tick()
    # engine loads now: ss0=600 (hot, single shard), ss1=180, ss2=0
    move = bd.streams.rebalance("tri.stream")
    assert move["from"] == "streamstore1" and move["to"] == "streamstore2"


def test_empty_batch_append_is_a_noop():
    s = Stream("e", ("x",), capacity=8)
    assert s.append({"x": []}) == {"appended": 0, "dropped": 0, "rows": 0}
    s.append({"x": [1.0, 2.0]})
    assert s.append({"x": []})["rows"] == 2
    _, sh = _mk_pair(shards=2)
    assert sh.append({"x": [], "y": []})["appended"] == 0
    assert sh.num_rows == 0


def test_rolling_sums_reanchor_each_ring_generation():
    """Once per ring generation the cumulative slots are rewritten as
    buffered-only prefix sums, so the running totals stay bounded and
    range_sum precision can't drift over a long-lived stream."""
    s = Stream("r", ("x",), capacity=8)
    s.append({"x": np.full(8, 1e9)})
    assert s.window_aggregate(8, "sum", "x") == pytest.approx(8e9)
    assert "x" in s._cum                       # lazily built on first use
    s.append({"x": np.full(56, 1e9)})          # crosses generations
    s.append({"x": np.arange(8, dtype=float)})  # crosses again
    assert s._running["x"] == pytest.approx(np.arange(8).sum())
    assert s.range_sum("x", 2, 6) == pytest.approx(2 + 3 + 4 + 5)
    assert s.window_aggregate(8, "sum", "x") == pytest.approx(28.0)


def test_rolling_sums_stay_precise_with_large_magnitudes():
    """Steady small-batch ingest of epoch-millisecond-sized values: the
    O(1) fast path must keep matching a directly materialized window
    (without re-anchoring, the lifetime running total exceeds 2**53 and
    the prefix-sum subtraction visibly drifts)."""
    rng = np.random.default_rng(0)
    s = Stream("ts", ("t",), capacity=256)
    s.append({"t": rng.uniform(1e12, 2e12, 128)})
    s.window_aggregate(128, "sum", "t")        # build the cum ring early
    for _ in range(2000):                      # 128k rows, 64 per batch
        s.append({"t": rng.uniform(1e12, 2e12, 64)})
    k = s.total_appended // 128 - 1
    first, arrs = s.ordered_arrays()           # raw float64 ring values
    exact = float(arrs["t"][k * 128 - first:(k + 1) * 128 - first].sum())
    assert abs(s.window_aggregate(128, "sum", "t") - exact) < 1.0


def test_scatter_vectorized_path_matches_segment_path():
    """A batch spanning many small blocks takes the vectorized owner
    path; distribution and gather must match the segment path exactly."""
    ref, sh_seg = _mk_pair(shards=3, capacity=4096, block_rows=4)
    _, sh_vec = _mk_pair(shards=3, capacity=4096, block_rows=4)
    rng = np.random.default_rng(8)
    batch = {"x": rng.standard_normal(600), "y": rng.standard_normal(600)}
    ref.append(batch)
    for part in (dict(x=batch["x"][:100], y=batch["y"][:100]),
                 dict(x=batch["x"][100:], y=batch["y"][100:])):
        sh_seg.append(part)                     # 25 blocks: segment path
    sh_vec.append(batch)                        # 150 blocks: vectorized
    for view in (lambda s: s.snapshot().columns["y"],
                 lambda s: s.window(128).attrs["x"]):
        np.testing.assert_array_equal(np.asarray(view(ref)),
                                      np.asarray(view(sh_vec)))
        np.testing.assert_array_equal(np.asarray(view(sh_vec)),
                                      np.asarray(view(sh_seg)))


def test_nan_shard_key_routes_deterministically():
    import warnings
    _, sh = _mk_pair(shards=2, fields=("k", "v"), shard_key="k")
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # any RuntimeWarning fails
        sh.append({"k": [1.0, float("nan"), float("inf"),
                         float("-inf"), 2.0],
                   "v": [10.0, 20.0, 30.0, 40.0, 50.0]})
    # non-finite keys land on shard 0; the gather still sees every row
    np.testing.assert_array_equal(
        np.asarray(sh.snapshot().columns["v"]),
        [10.0, 20.0, 30.0, 40.0, 50.0])


def test_num_engines_respected_in_grown_deployment():
    """A deployment whose streaming island is already larger must still
    honor the requested num_engines spread."""
    bd = default_deployment(stream_engines=4)
    sh = bd.register_stream("streamstore0", "narrow.stream", ("x",),
                            capacity=256, shards=4, num_engines=2)
    assert sh.shard_engines() == ["streamstore0", "streamstore1",
                                  "streamstore0", "streamstore1"]


def test_lopsided_detection_tracks_current_load_not_lifetime():
    """Late-onset skew: a long-balanced stream whose traffic suddenly
    piles onto one shard.  Lifetime appended/dropped counters stay
    near-balanced (history dominates), so the old detector missed it;
    the per-tick EWMA flags the newly hot shard within a few ticks, and
    the formerly busy shard's load decays instead of charging its donor
    engine forever."""
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "onset.stream", ("k", "v"),
                            capacity=65536, shards=2, shard_key="k",
                            num_engines=2)
    rng = np.random.default_rng(9)
    # phase 1: 10 balanced ticks (alternating keys -> both shards even)
    for _ in range(10):
        sh.append({"k": np.tile([0.0, 1.0], 64),
                   "v": rng.standard_normal(128)})
        bd.streams.tick()
    assert bd.monitor.lopsided_shards("onset.stream") == []
    # phase 2: traffic flips entirely onto shard 1
    for _ in range(8):
        sh.append({"k": np.ones(128), "v": rng.standard_normal(128)})
        bd.streams.tick()
    stats = bd.monitor.shard_stats["onset.stream"]
    lifetime = {i: Monitor.shard_load(st) for i, st in stats.items()}
    # the lifetime view still looks balanced (under the 3x threshold)...
    assert lifetime[1] < 3.0 * lifetime[0]
    # ...but the EWMA sees the current skew and flags shard 1
    assert bd.monitor.lopsided_shards("onset.stream") == [1]
    loads = bd.monitor.shard_loads("onset.stream")
    assert loads[1] > 3.0 * loads[0]
    # the idle shard's load decayed well below its lifetime ingest —
    # its engine is no longer charged for historical rows
    assert loads[0] < 0.2 * lifetime[0]


def test_rebalance_uses_current_loads_after_traffic_shift():
    """The mover and the detector share the EWMA view: after the shift,
    rebalance moves the *currently* hot shard off its engine even though
    lifetime counters would call the placement fine."""
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "shift.stream", ("k", "v"),
                            capacity=65536, shards=4, shard_key="k",
                            num_engines=2)
    rng = np.random.default_rng(10)
    for _ in range(10):                    # balanced history, all shards
        sh.append({"k": np.tile([0.0, 1.0, 2.0, 3.0], 32),
                   "v": rng.standard_normal(128)})
        bd.streams.tick()
    for _ in range(8):                     # now only shard 1 is hot
        sh.append({"k": np.ones(256), "v": rng.standard_normal(256)})
        bd.streams.tick()
    hot_engine = sh.shard_stats()[1]["engine"]
    move = bd.streams.rebalance("shift.stream")
    # the currently hot engine donates (lifetime counters would have
    # weighed all four shards near-equal and could pick either side)
    assert move["from"] == hot_engine


# -- background tick driver ---------------------------------------------------
def test_background_driver_ticks_and_stops_leak_free():
    bd = default_deployment()
    bd.register_stream("streamstore0", "t.stream", ("x",), capacity=64)
    stream = bd.engines["streamstore0"].get("t.stream")
    cq = bd.register_continuous("bdstream(snapshot(t.stream))",
                                name="snap")
    stream.append({"x": [1.0, 2.0]})
    before = threading.active_count()
    bd.streams.start(interval_seconds=0.01)
    with pytest.raises(RuntimeError):            # double-start refused
        bd.streams.start(interval_seconds=0.01)
    deadline = time.monotonic() + 5.0
    while bd.streams.driver_ticks < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert bd.streams.driver_running
    assert bd.streams.stop()
    assert not bd.streams.driver_running
    ticks = bd.streams.ticks
    time.sleep(0.05)
    assert bd.streams.ticks == ticks             # really stopped
    assert cq.executions >= 3
    assert not any(t.name == "stream-tick-driver"
                   for t in threading.enumerate())
    assert threading.active_count() <= before + 1
    # restart works after a clean stop; stop with no driver reports False
    bd.streams.start(interval_seconds=0.01)
    assert bd.streams.stop()
    assert bd.streams.stop() is False
    st = bd.streams.status()["background"]
    assert st["running"] is False and st["driver_ticks"] >= 3


def test_background_driver_survives_tick_exceptions(monkeypatch):
    """An unexpected error outside per-query isolation is recorded but
    must not kill the daemon thread."""
    bd = default_deployment()
    boom = {"left": 2}
    real_tick = bd.streams.tick

    def flaky_tick():
        if boom["left"]:
            boom["left"] -= 1
            raise RuntimeError("injected")
        return real_tick()

    monkeypatch.setattr(bd.streams, "tick", flaky_tick)
    bd.streams.start(interval_seconds=0.01)
    deadline = time.monotonic() + 5.0
    while bd.streams.driver_ticks < 4 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert bd.streams.driver_running          # survived the bad ticks
    bd.streams.stop()
    st = bd.streams.status()["background"]
    assert st["driver_errors"] == 2
    assert "injected" in st["last_driver_error"]
    assert bd.streams.ticks >= 2              # real ticks resumed


# -- admin surface ------------------------------------------------------------
def test_status_reports_per_shard_stats():
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "vitals.stream", ("hr",),
                            capacity=512, shards=4, num_engines=2,
                            block_rows=4)
    sh.append({"hr": np.arange(64, dtype=float)})
    bd.streams.tick()
    st = admin.status(bd)
    info = st["streams"]["streams"]["vitals.stream"]
    assert set(info["shards"]) == {0, 1, 2, 3}
    for shard in info["shards"].values():
        assert {"engine", "rows", "appended", "dropped"} <= set(shard)
    assert info["engine"] == ["streamstore0", "streamstore1",
                              "streamstore0", "streamstore1"]
    assert st["streams"]["background"]["running"] is False
    # shard rings don't show up as top-level streams
    assert not any("@shard" in name
                   for name in st["streams"]["streams"])
    # the Monitor holds the same per-shard snapshot (rebalance signal)
    assert set(bd.monitor.shard_stats["vitals.stream"]) == {0, 1, 2, 3}
