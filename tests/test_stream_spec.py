"""StreamSpec API redesign tests: spec <-> legacy-kwargs equivalence
(hypothesis over the config space), the deprecation shim's parity with
the spec path (bit-identical streams), the durability manifest
round-trip (``recover_stream`` hands the registration spec back), and
the frozen-shim guarantee ``tools/check_api_freeze.py`` enforces in
CI."""
import json
import warnings

import numpy as np
import pytest

from repro.core.api import default_deployment
from repro.stream import durability as dur
from repro.stream.spec import (LEGACY_KWARGS, Durability, EventTime,
                               Sharding, StreamSpec)


# -- spec construction & validation -------------------------------------------

def test_spec_is_frozen_and_hashable():
    spec = StreamSpec("s", ("ts", "v"), capacity=64,
                      sharding=Sharding(shards=2),
                      event_time=EventTime("ts", max_delay=1.0))
    with pytest.raises(Exception):
        spec.capacity = 1
    assert spec == StreamSpec("s", ["ts", "v"], capacity=64,
                              sharding=Sharding(shards=2),
                              event_time=EventTime("ts", max_delay=1.0))
    assert len({spec, spec}) == 1        # usable as a dict/config key


@pytest.mark.parametrize("bad", [
    lambda: StreamSpec("", ("v",)),
    lambda: StreamSpec("s", ()),
    lambda: StreamSpec("s", ("v",), capacity=0),
    lambda: StreamSpec("s", ("v",), event_time=EventTime("ts")),
    lambda: StreamSpec("s", ("v",),
                       sharding=Sharding(shards=2, shard_key="k")),
    lambda: Sharding(shards=1),
    lambda: Sharding(shards=2, num_engines=3),
    lambda: Sharding(shards=2, block_rows=0),
    lambda: EventTime(""),
    lambda: EventTime("ts", max_delay=-1.0),
    lambda: EventTime("ts", idle_timeout=0.0),
    lambda: Durability(""),
    lambda: Durability("d", checkpoint_every_rows=0),
    lambda: Durability("d", keep=0),
])
def test_spec_validation_rejects(bad):
    with pytest.raises(ValueError):
        bad()


def test_dead_letter_requires_event_time():
    with pytest.raises(ValueError):
        StreamSpec.from_kwargs("s", ("v",), dead_letter=True)


def test_num_engines_normalizes_to_shards():
    assert Sharding(shards=3) == Sharding(shards=3, num_engines=3)


# -- spec <-> kwargs equivalence ----------------------------------------------

def test_kwargs_round_trip_plain_and_full():
    for spec in (
            StreamSpec("a", ("v",)),
            StreamSpec("b", ("ts", "k", "v"), capacity=256,
                       rolling=False,
                       sharding=Sharding(shards=3, shard_key="k",
                                         num_engines=2, block_rows=8),
                       event_time=EventTime("ts", max_delay=2.0,
                                            idle_timeout=0.5,
                                            dead_letter=True),
                       durability=Durability("/tmp/x",
                                             checkpoint_every_rows=7))):
        again = StreamSpec.from_kwargs(spec.name, spec.fields,
                                       **spec.to_kwargs())
        assert again == spec


def test_to_kwargs_rejects_inexpressible_keep():
    spec = StreamSpec("s", ("v",),
                      durability=Durability("/tmp/x", keep=5))
    with pytest.raises(ValueError):
        spec.to_kwargs()


def test_spec_equivalence_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")  # noqa: F841
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    shardings = st.one_of(st.none(), st.builds(
        Sharding,
        shards=st.integers(2, 6),
        shard_key=st.sampled_from([None, "k"]),
        block_rows=st.integers(1, 128)))
    event_times = st.one_of(st.none(), st.builds(
        EventTime,
        ts_field=st.just("ts"),
        max_delay=st.floats(0.0, 10.0, allow_nan=False),
        idle_timeout=st.one_of(st.none(), st.floats(0.1, 5.0)),
        dead_letter=st.booleans()))
    durabilities = st.one_of(st.none(), st.builds(
        Durability,
        directory=st.just("/tmp/spec-prop"),
        checkpoint_every_rows=st.one_of(st.none(),
                                        st.integers(1, 1000))))

    @settings(max_examples=60, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(capacity=st.integers(1, 1 << 16), rolling=st.booleans(),
           sharding=shardings, event_time=event_times,
           durability=durabilities)
    def check(capacity, rolling, sharding, event_time, durability):
        spec = StreamSpec("prop.s", ("ts", "k", "v"),
                          capacity=capacity, rolling=rolling,
                          sharding=sharding, event_time=event_time,
                          durability=durability)
        # every spec in the config space has an equivalent legacy
        # kwargs spelling, and folding it back is the identity
        assert StreamSpec.from_kwargs("prop.s", ("ts", "k", "v"),
                                      **spec.to_kwargs()) == spec

    check()


# -- deprecation shim: warns, and stays bit-identical -------------------------

def test_legacy_kwargs_emit_deprecation_warning():
    bd = default_deployment()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        bd.register_stream("streamstore0", "w.s", ("v",), capacity=16)
    assert any(issubclass(w.category, DeprecationWarning)
               and "StreamSpec" in str(w.message) for w in caught)


def test_spec_path_emits_no_warning():
    bd = default_deployment()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        bd.register_stream("streamstore0",
                           StreamSpec("w.t", ("v",), capacity=16))
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]


def test_register_stream_rejects_mixed_forms():
    bd = default_deployment()
    spec = StreamSpec("m.s", ("v",))
    with pytest.raises(TypeError):
        bd.register_stream("streamstore0", spec, spec=spec)
    with pytest.raises(TypeError):
        bd.register_stream("streamstore0", "m.s", ("v",), spec=spec)


@pytest.mark.parametrize("sharded", [False, True])
def test_shim_parity_bit_identical(sharded, tmp_path):
    """The acceptance criterion: a stream registered through the
    legacy shim is bit-identical to one registered with the equivalent
    spec, after identical ingest."""
    kwargs = dict(capacity=64, ts_field="ts", max_delay=1.0,
                  dead_letter=True,
                  durability=str(tmp_path / "legacy"))
    if sharded:
        kwargs.update(shards=2, block_rows=8)
    bd1 = default_deployment()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        s1 = bd1.register_stream("streamstore0", "p.s", ("ts", "v"),
                                 **kwargs)
    spec = StreamSpec.from_kwargs("p.s", ("ts", "v"), **{
        **kwargs, "durability": str(tmp_path / "spec")})
    bd2 = default_deployment()
    s2 = bd2.register_stream("streamstore0", spec)
    assert type(s1) is type(s2)
    rng = np.random.default_rng(7)
    for _ in range(4):
        ts = np.cumsum(rng.random(32)) * 2.0
        batch = {"ts": ts, "v": rng.standard_normal(32)}
        s1.append({k: v.copy() for k, v in batch.items()})
        s2.append(batch)
    fp1, fp2 = dur.fingerprint(s1), dur.fingerprint(s2)
    assert fp1 == fp2
    # the shim also records the spec it built (same spec, modulo the
    # two directories)
    import dataclasses
    assert s1.spec == dataclasses.replace(
        spec, durability=dataclasses.replace(
            spec.durability, directory=str(tmp_path / "legacy")))
    assert s2.spec == spec


# -- manifest round-trip ------------------------------------------------------

@pytest.mark.parametrize("sharded", [False, True])
def test_manifest_round_trips_spec(sharded, tmp_path):
    bd = default_deployment()
    sharding = Sharding(shards=3, num_engines=2,
                        block_rows=16) if sharded else None
    spec = StreamSpec("m.rt", ("ts", "v"), capacity=100,
                      sharding=sharding,
                      event_time=EventTime("ts", max_delay=1.5,
                                           idle_timeout=2.0,
                                           dead_letter=True),
                      durability=Durability(str(tmp_path),
                                            checkpoint_every_rows=32))
    s = bd.register_stream("streamstore0", spec)
    s.append({"ts": np.arange(8, dtype=float), "v": np.zeros(8)})
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert StreamSpec.from_manifest(meta, str(tmp_path)) == spec
    # without a directory the durability leg is dropped (the manifest
    # never records where it lives)
    assert StreamSpec.from_manifest(meta) == \
        StreamSpec(spec.name, spec.fields, capacity=spec.capacity,
                   rolling=spec.rolling, sharding=spec.sharding,
                   event_time=spec.event_time)


def test_recover_stream_returns_spec(tmp_path):
    bd = default_deployment()
    spec = StreamSpec("r.rt", ("ts", "v"), capacity=64,
                      sharding=Sharding(shards=2),
                      durability=Durability(str(tmp_path),
                                            checkpoint_every_rows=16))
    s = bd.register_stream("streamstore0", spec)
    s.append({"ts": np.arange(20, dtype=float), "v": np.arange(20.)})
    fp = dur.fingerprint(s)
    s._durable.close()
    bd2 = default_deployment()
    recovered = bd2.recover_stream("streamstore0", str(tmp_path))
    # recovery no longer requires restating registration kwargs: the
    # spec rides the checkpoint manifest
    assert recovered.spec == spec
    assert dur.fingerprint(recovered) == fp


# -- the freeze lint ----------------------------------------------------------

def test_register_stream_shim_is_frozen():
    """Tier-1 twin of tools/check_api_freeze.py: the legacy kwargs
    surface must match spec.LEGACY_KWARGS exactly — new knobs belong
    on the StreamSpec sub-configs."""
    import inspect

    from repro.core.api import BigDawg
    params = [p for p in
              inspect.signature(BigDawg.register_stream).parameters
              if p != "self"]
    assert params == ["engine_name", "name", "fields",
                      *LEGACY_KWARGS, "spec"]


def test_check_api_freeze_tool_passes():
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, "tools/check_api_freeze.py"],
        capture_output=True, text=True, cwd=".",
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr
