"""Substrate tests: optimizer, schedules, checkpointing (atomic/elastic/
async), fault-tolerant recovery determinism, data pipeline determinism,
straggler mitigation, tensorstore placement policies."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.api import default_deployment
from repro.core.monitor import Monitor
from repro.core.tensorstore import PlacementPolicy, TensorPolystore
from repro.data.pipeline import DataConfig, TokenDataset, batch_as_table, \
    table_as_batch
from repro.models import registry
from repro.optim import adamw
from repro.runtime.fault import (FailureInjector, StragglerMitigator,
                                 run_with_recovery)
from repro.train.step import TrainConfig, init_train_state, make_train_step


# -- optimizer -------------------------------------------------------------------
def test_adamw_minimizes_quadratic():
    cfg = adamw.AdamWConfig(learning_rate=0.1, weight_decay=0.0,
                            warmup_steps=0, total_steps=200,
                            schedule="constant")
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state = adamw.apply_updates(cfg, params, grads, state)
    assert float(loss(params)) < 1e-3


def test_grad_clip_and_lr_schedule():
    grads = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    cfg = adamw.AdamWConfig(learning_rate=1e-3, warmup_steps=10,
                            total_steps=100)
    lrs = [float(adamw.lr_at(cfg, jnp.int32(s))) for s in (0, 9, 50, 99)]
    assert lrs[0] < lrs[1]                      # warmup rises
    assert lrs[1] > lrs[2] > lrs[3]             # cosine decays


def test_int8_moment_compression_roundtrip():
    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal((64, 32)), jnp.float32)}
    state = adamw.init_state(params)
    state["v"] = jax.tree.map(
        lambda p: jnp.abs(p) * 0.01, params)     # nonzero moments
    comp = adamw.compress_moments_int8(state)
    back = adamw.decompress_moments_int8(comp)
    err = float(jnp.max(jnp.abs(back["v"]["w"] - state["v"]["w"])))
    assert err <= float(jnp.max(state["v"]["w"])) / 127.0 * 1.01


# -- checkpointing ----------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6.0).reshape(2, 3),
             "nested": {"b": jnp.int32(7)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x + step, state))
    assert mgr.all_steps() == [2, 3]            # keep=2 gc'd step 1
    restored, step = mgr.restore(state)
    assert step == 3
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(state["a"]) + 3)
    assert int(restored["nested"]["b"]) == 10


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": jnp.ones((128, 128))}
    mgr.save(5, state, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_elastic_restore_via_shardings(tmp_path):
    """Restore with explicit (single-device) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state)
    shardings = {"w": NamedSharding(mesh, P(None, None))}
    restored, _ = mgr.restore(state, shardings=shardings)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(state["w"]))


# -- fault tolerance ----------------------------------------------------------------
def test_recovery_trajectory_matches_failure_free(tmp_path):
    """Training WITH injected failures must land on the same final state as
    failure-free training (checkpoint/restart + deterministic data)."""
    cfg = registry.get_config("qwen2-1.5b", reduced=True)
    tcfg = TrainConfig(optimizer=adamw.AdamWConfig(total_steps=20,
                                                   warmup_steps=2))
    step_jit = jax.jit(make_train_step(cfg, tcfg))
    ds = TokenDataset(cfg, DataConfig(seq_len=16, global_batch=2))

    def make_step_fn():
        def fn(state, i):
            out, _ = step_jit(state, jax.tree.map(jnp.asarray,
                                                  ds.batch_at(i)))
            return out
        return fn

    def init():
        return init_train_state(cfg, jax.random.PRNGKey(5))

    clean = init()
    fn = make_step_fn()
    for i in range(12):
        clean = fn(clean, i)

    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    rep = run_with_recovery(
        init_state=init, step_fn=fn, ckpt=mgr, num_steps=12,
        checkpoint_every=3, injector=FailureInjector({5: 0, 9: 1}))
    assert rep.failures_recovered == 2
    recovered, final_step = mgr.restore(init())
    # compare a parameter leaf after identical total steps
    ref_leaf = jax.tree.leaves(clean["params"])[0]
    # re-run the recovered state forward to step 12 if checkpoint < 12
    state = recovered
    for i in range(final_step + 1, 12):
        state = fn(state, i)
    got_leaf = jax.tree.leaves(state["params"])[0]
    np.testing.assert_allclose(np.asarray(got_leaf, np.float32),
                               np.asarray(ref_leaf, np.float32),
                               atol=1e-6)


def test_straggler_mitigation_rebalances():
    mon = Monitor()
    mit = StragglerMitigator(mon, factor=2.0)
    for _ in range(10):
        for h in range(4):
            mit.observe(h, 0.01 if h != 2 else 0.2)
    assert mit.slow_hosts() == [2]
    weights = mit.rebalance(4)
    assert weights[2] < weights[0]
    assert abs(sum(weights.values()) - 1.0) < 1e-9


# -- data pipeline ------------------------------------------------------------------
def test_data_determinism_and_host_sharding():
    cfg = registry.get_config("qwen2-1.5b", reduced=True)
    a = TokenDataset(cfg, DataConfig(seq_len=16, global_batch=4,
                                     num_hosts=2, host_id=0))
    b = TokenDataset(cfg, DataConfig(seq_len=16, global_batch=4,
                                     num_hosts=2, host_id=1))
    a1, a2 = a.batch_at(3), a.batch_at(3)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])
    assert not np.array_equal(a.batch_at(3)["tokens"],
                              b.batch_at(3)["tokens"])
    assert not np.array_equal(a.batch_at(3)["tokens"],
                              a.batch_at(4)["tokens"])
    assert a1["tokens"].shape == (2, 16)        # local = global/hosts
    assert a1["tokens"].max() < cfg.vocab_size


def test_batch_table_roundtrip():
    cfg = registry.get_config("qwen2-1.5b", reduced=True)
    ds = TokenDataset(cfg, DataConfig(seq_len=8, global_batch=2))
    batch = ds.batch_at(0)
    table = batch_as_table(batch)
    back = table_as_batch(table, 2, 8)
    np.testing.assert_array_equal(np.asarray(back["tokens"]),
                                  batch["tokens"])
    np.testing.assert_array_equal(np.asarray(back["labels"]),
                                  batch["labels"])


# -- tensorstore placement ------------------------------------------------------------
@pytest.mark.parametrize("policy", ["resident", "offload", "compressed"])
def test_tensorstore_policies_roundtrip(policy):
    cfg = registry.get_config("qwen2-1.5b", reduced=True)
    bd = default_deployment()
    ts = TensorPolystore(bd, PlacementPolicy(moments=policy))
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    state["opt"]["v"] = jax.tree.map(
        lambda p: jnp.abs(p.astype(jnp.float32)) * 0.05, state["params"])
    ts.register_train_state("t", state)
    back = ts.fetch_train_state("t")
    v0 = jax.tree.leaves(state["opt"]["v"])[0]
    v1 = jax.tree.leaves(back["opt"]["v"])[0]
    tol = (float(jnp.max(jnp.abs(v0))) / 127.0 * 1.01
           if policy == "compressed" else 1e-7)
    assert float(jnp.max(jnp.abs(jnp.asarray(v0) - jnp.asarray(v1)))) <= tol


def test_tensorstore_kv_cache_int8():
    cfg = registry.get_config("qwen2-1.5b", reduced=True)
    bd = default_deployment()
    ts = TensorPolystore(bd, PlacementPolicy(kv_codec="int8"))
    cache = registry.init_cache(cfg, 2, 16)
    cache = jax.tree.map(
        lambda c: (jnp.asarray(np.random.default_rng(0).standard_normal(
            c.shape), c.dtype) if c.dtype != jnp.int32 else c), cache)
    ts.register_kv_cache("t", cache)
    back = ts.fetch_kv_cache("t", template=cache)
    l0 = jax.tree.leaves(cache)[0]
    l1 = jax.tree.leaves(back)[0]
    assert l0.shape == l1.shape
