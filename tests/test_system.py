"""End-to-end behaviour tests: the full polystore-managed training and
serving workflow — data pipeline through the RelationalIsland, train steps
with polystore-registered state, serving with KV-cache waves, and the
paper's §VII claims as executable assertions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bql, signatures
from repro.core.api import default_deployment
from repro.core.migrator import MigrationParams
from repro.core.tensorstore import PlacementPolicy, TensorPolystore
from repro.data.mimic import load_mimic_demo
from repro.data.pipeline import DataConfig, TokenDataset, batch_as_table, \
    table_as_batch
from repro.models import registry
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import Request, Scheduler, ServeConfig, ServeSession
from repro.train.step import TrainConfig, init_train_state, make_train_step


def test_end_to_end_polystore_training_loop():
    """Batches flow HostStore(relational) -> cast -> device train step;
    model state is registered in the catalog; loss decreases."""
    cfg = registry.get_config("qwen2-1.5b", reduced=True)
    bd = default_deployment()
    ts = TensorPolystore(bd, PlacementPolicy(moments="resident"))
    tcfg = TrainConfig(optimizer=AdamWConfig(
        learning_rate=3e-3, total_steps=30, warmup_steps=3))
    step = jax.jit(make_train_step(cfg, tcfg))
    ds = TokenDataset(cfg, DataConfig(seq_len=16, global_batch=4, seed=1))
    state = init_train_state(cfg, jax.random.PRNGKey(0))

    losses = []
    for i in range(15):
        raw = ds.batch_at(0)                   # same batch -> must overfit
        # route through the relational island + migrator (polystore path)
        bd.engines["hoststore0"].put("train_batch", batch_as_table(raw))
        bd.migrator.migrate(bd.engines["hoststore0"], "train_batch",
                            bd.engines["densehbm0"], "train_batch_dev",
                            MigrationParams(method="binary"))
        table = bd.engines["densehbm0"].get("train_batch_dev")
        batch = table_as_batch(table, 4, 16)
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses

    ts.register_train_state("qwen2-reduced", state)
    rows = bd.query("bdcatalog(select name from objects)").value
    assert any("qwen2-reduced/params" == r["name"] for r in rows)


def test_paper_claim_migration_queries_slower():
    """§VII: queries requiring migration take more time than single-island
    queries (same data, measured on this deployment)."""
    bd = default_deployment()
    load_mimic_demo(bd, num_patients=64, num_orders=2048)
    single = "bdrel(select poe_id, subject_id from mimic2v26.poe_order)"
    casted = ("bdarray(scan(bdcast(bdrel(select poe_id, subject_id from"
              " mimic2v26.poe_order), pc,"
              " '<subject_id:int32>[poe_id=0:*,10000,0]', array)))")

    def timed(q):
        ts = []
        for _ in range(5):
            r = bd.query(q)
            ts.append(sum(s for n, s in r.stages
                          if "Parse" not in n and "enumeration" not in n
                          and "Monitor" not in n))
        return float(np.median(ts))

    assert timed(casted) > timed(single)


def test_paper_claim_binary_faster_than_staged():
    """§V.C: binary migration beats the format-translating staged path."""
    import time
    bd = default_deployment()
    load_mimic_demo(bd, num_orders=4096)
    src, dst = bd.engines["hoststore0"], bd.engines["densehbm0"]

    def timed(method):
        ts = []
        for i in range(5):
            t0 = time.perf_counter()
            bd.migrator.migrate(src, "mimic2v26.poe_order", dst,
                                f"m_{method}_{i}",
                                MigrationParams(method=method))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    assert timed("binary") < timed("staged")


def test_serving_waves():
    cfg = registry.get_config("qwen2-1.5b", reduced=True)
    params = init_train_state(cfg, jax.random.PRNGKey(0))["params"]
    sess = ServeSession(cfg, params,
                        ServeConfig(max_batch=2, cache_len=32,
                                    max_new_tokens=4))
    sched = Scheduler(sess)
    for r in range(5):
        sched.submit(Request(r, np.arange(3 + r, dtype=np.int32),
                             max_new_tokens=3))
    done = sched.run()
    assert len(done) == 5
    assert all(len(c.tokens) == 3 for c in done)
    assert all(int(t) < cfg.vocab_size for c in done for t in c.tokens)


def test_serving_parallel_waves_deterministic():
    """max_parallel_waves > 1 overlaps waves on threads; completions must
    keep submission order and emit identical tokens to serial waves."""
    cfg = registry.get_config("qwen2-1.5b", reduced=True)
    params = init_train_state(cfg, jax.random.PRNGKey(0))["params"]
    runs = []
    for waves in (1, 2):
        sess = ServeSession(cfg, params,
                            ServeConfig(max_batch=2, cache_len=32,
                                        max_new_tokens=4,
                                        max_parallel_waves=waves))
        sched = Scheduler(sess)
        for r in range(5):
            sched.submit(Request(r, np.arange(3 + r, dtype=np.int32),
                                 max_new_tokens=3))
        runs.append(sched.run())
    serial, parallel = runs
    assert [c.rid for c in serial] == [c.rid for c in parallel]
    for cs, cp in zip(serial, parallel):
        np.testing.assert_array_equal(cs.tokens, cp.tokens)


def test_planner_lean_mode_not_worst_plan():
    """Monitor-informed selection: once trained, lean mode must not pick
    the slowest enumerated plan (the paper's core value proposition)."""
    bd = default_deployment()
    load_mimic_demo(bd, num_orders=2048)
    q = ("bdarray(scan(bdcast(bdrel(select poe_id, dose from"
         " mimic2v26.poe_order), dc,"
         " '<dose:double>[poe_id=0:*,10000,0]', array)))")
    bd.query(q, training=True)
    sig = signatures.of_query(bql.parse(q))
    perf = bd.monitor.get_benchmark_performance(sig)
    means = {k: float(np.mean(v)) for k, v in perf.items() if v}
    worst = max(means, key=means.get)
    r_lean = bd.query(q)
    assert r_lean.qep_id != worst or len(means) == 1
