#!/usr/bin/env python
"""CI lint: the legacy ``register_stream`` kwargs surface is FROZEN.

The spec redesign (src/repro/stream/spec.py) made StreamSpec the
primary registration form; the kwargs form survives only as a
deprecation shim.  New registration knobs must be added as fields on
the ``Sharding``/``EventTime``/``Durability`` sub-configs (where they
round-trip through manifests, ServeConfig, and the front door for
free) — never as new keyword parameters on the shim.

This script pins the shim's signature to exactly
``spec.LEGACY_KWARGS`` + ``spec`` and exits non-zero on drift, so a
PR that grows the shim fails the lint job with an actionable message.

  PYTHONPATH=src python tools/check_api_freeze.py
"""
import inspect
import sys


def main() -> int:
    from repro.core.api import BigDawg
    from repro.stream.spec import LEGACY_KWARGS

    sig = inspect.signature(BigDawg.register_stream)
    params = [p for p in sig.parameters if p != "self"]
    expected = ["engine_name", "name", "fields", *LEGACY_KWARGS, "spec"]
    if params == expected:
        print(f"ok: register_stream signature is frozen "
              f"({len(LEGACY_KWARGS)} legacy kwargs + spec)")
        return 0
    added = [p for p in params if p not in expected]
    removed = [p for p in expected if p not in params]
    print("register_stream's legacy shim signature drifted from "
          "repro.stream.spec.LEGACY_KWARGS:", file=sys.stderr)
    if added:
        print(f"  added:   {added}\n"
              f"  -> add new registration knobs to a StreamSpec "
              f"sub-config (Sharding/EventTime/Durability) instead; "
              f"the kwargs form is a frozen deprecation shim",
              file=sys.stderr)
    if removed:
        print(f"  removed: {removed}\n"
              f"  -> removing shim kwargs breaks callers; if a knob "
              f"was intentionally retired, update LEGACY_KWARGS and "
              f"this check's expectation together", file=sys.stderr)
    if not added and not removed:
        print(f"  reordered: {params}\n  expected:  {expected}",
              file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
