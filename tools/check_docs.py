"""Docs-consistency gate: extract every fenced ``bql`` / ``python``
example from docs/BQL.md *and* docs/OPERATIONS.md and execute it
against an in-memory deployment, so the documentation cannot silently
rot (wired into CI).

  PYTHONPATH=src python tools/check_docs.py [--docs docs/BQL.md ...]

Harness contract (documented at the top of docs/BQL.md):

- ``bql`` blocks: each blank-line-separated statement is one query sent
  through ``bd.query(...)``; it must parse, execute, and return a value.
- ``python`` blocks: executed with ``bd`` and ``np`` in scope (assertions
  inside them are part of the gate).

Blocks run in document order against one shared deployment, so examples
may rely on the fixture state below plus any earlier example's effects.
"""
from __future__ import annotations

import argparse
import re
import sys
import traceback
from typing import List, Tuple

import numpy as np

_FENCE_RE = re.compile(r"^```(\w*)\s*$")


def extract_blocks(text: str) -> List[Tuple[str, int, str]]:
    """[(language, first line number, block body)] for fenced blocks."""
    blocks, lang, start, buf = [], None, 0, []
    for i, line in enumerate(text.splitlines(), 1):
        m = _FENCE_RE.match(line)
        if m and lang is None:
            lang, start, buf = m.group(1).lower(), i + 1, []
        elif line.strip() == "```" and lang is not None:
            blocks.append((lang, start, "\n".join(buf)))
            lang = None
        elif lang is not None:
            buf.append(line)
    return blocks


def build_fixture():
    """The deployment the documented examples run against (keep in sync
    with the fixture description in docs/BQL.md)."""
    from repro.core.api import default_deployment
    from repro.data.mimic import load_mimic_demo
    from repro.stream.spec import EventTime, Sharding, StreamSpec

    bd = default_deployment()
    load_mimic_demo(bd, num_patients=16, num_orders=64, wave_len=256,
                    num_logs=16)
    vitals = bd.register_stream("streamstore0", StreamSpec(
        "vitals.stream", ("hr",), capacity=64))
    vitals.append({"hr": [72.0, 75.0, 71.0, 78.0]})
    seq = np.arange(64, dtype=np.float64)
    waves = bd.register_stream("streamstore0", StreamSpec(
        "mimic2v26.waveform_stream", ("signal", "hr"), capacity=1024,
        sharding=Sharding(shards=2, block_rows=8)))
    waves.append({"signal": np.sin(2 * np.pi * seq / 360.0),
                  "hr": 75.0 + seq % 7})
    # event-time pair: 48 rows each on a shared ts axis (ECG offset by
    # 0.25), delivered OUT OF ORDER (adjacent pairs swapped — bounded
    # displacement 1 < max_delay) so watermarks/insertion buffers do
    # real work in the documented examples; both sharded 2x over the
    # same engines, so the documented join takes the partial path
    ts = np.arange(48, dtype=np.float64)
    swap = ts.astype(np.int64) ^ 1                 # 1,0,3,2,5,4,...
    for name, field, offset in (("icu.abp", "abp", 0.0),
                                ("icu.ecg", "ecg", 0.25)):
        s = bd.register_stream("streamstore0", StreamSpec(
            name, ("ts", field), capacity=512,
            sharding=Sharding(shards=2, block_rows=8),
            event_time=EventTime("ts", max_delay=4.0)))
        value = (90.0 + np.sin(ts) if field == "abp"
                 else np.cos(ts))
        s.append({"ts": (ts + offset)[swap], field: value[swap]})
    return bd


def statements(block: str) -> List[str]:
    """Statements of a bql block: separated by blank lines or comment
    lines (a comment must never bridge two statements into one)."""
    stmts, buf = [], []
    for line in block.splitlines() + [""]:
        if line.strip() and not line.strip().startswith("#"):
            buf.append(line)
        elif buf:
            stmts.append("\n".join(buf).strip())
            buf = []
    return stmts


def run_pass(docs: str, runnable, backend: str):
    """Execute every runnable block against a fresh fixture under one
    query backend; returns (examples run, failures)."""
    import os

    os.environ["REPRO_QUERY_BACKEND"] = backend
    bd = build_fixture()
    namespace = {"bd": bd, "np": np}
    ran, failures = 0, []
    for lang, line_no, body in runnable:
        if lang == "python":
            try:
                exec(compile(body, f"{docs}:{line_no}", "exec"),
                     namespace)
                ran += 1
            except Exception:                          # noqa: BLE001
                failures.append((line_no, body, traceback.format_exc()))
            continue
        for stmt in statements(body):
            flat = " ".join(stmt.split())
            try:
                response = bd.query(flat)
                assert response.value is not None, "query returned None"
                ran += 1
            except Exception:                          # noqa: BLE001
                failures.append((line_no, flat, traceback.format_exc()))
    return ran, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", nargs="*",
                    default=["docs/BQL.md", "docs/OPERATIONS.md"])
    args = ap.parse_args()

    # every documented example must run under BOTH query backends: the
    # docs describe one language, and the compiled path promises the
    # interpreter's results — a doc example that only works interpreted
    # is a parity bug, not a doc bug
    from repro.stream import compile as query_compile
    backends = ["interpreter"]
    if query_compile.JAX_AVAILABLE:
        backends.append("jit")
    else:
        print("note: jax unavailable — jit pass skipped")

    bad = 0
    for docs in args.docs:
        with open(docs) as fh:
            text = fh.read()
        blocks = extract_blocks(text)
        runnable = [(lang, ln, body) for lang, ln, body in blocks
                    if lang in ("bql", "python")]
        if not runnable:
            print(f"FAIL: no runnable bql/python blocks in {docs}")
            return 1
        for backend in backends:
            ran, failures = run_pass(docs, runnable, backend)
            for line_no, snippet, tb in failures:
                print(f"\nFAIL [{backend}] {docs}:{line_no}\n"
                      f"  {snippet}\n{tb}")
            status = "FAIL" if failures else "OK"
            print(f"{status} [{backend}]: {ran} documented examples "
                  f"executed, {len(failures)} failed ({docs})")
            bad += len(failures)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
