"""jit-parity gate: execute the full compiled op family under BOTH
query backends in one process and require **bitwise** equality — values,
dtypes, column order — plus a clean fallback ledger (the compiled path
must have actually served every family query, not quietly handed it
back to the interpreter).

  PYTHONPATH=src python tools/check_jit_parity.py

Run by CI's jit-parity job after the twice-run pytest suites: the
suites prove each backend is self-consistent, this gate pins the two
backends to each other.  Exits 0 only when every query pair matches
and ``stats()["fallbacks"] == 0``.
"""
from __future__ import annotations

import os
import sys

import numpy as np


def build():
    from repro.core.api import default_deployment

    bd = default_deployment()
    rng = np.random.default_rng(2026)
    p = bd.register_stream("streamstore0", "g.p", ("v", "w"),
                           capacity=512)
    s = bd.register_stream("streamstore0", "g.s", ("ts", "x"),
                           capacity=512, ts_field="ts", max_delay=0.0)
    a = bd.register_stream("streamstore0", "g.a", ("ts", "x"),
                           capacity=512, ts_field="ts", max_delay=0.0,
                           shards=2, num_engines=2)
    b = bd.register_stream("streamstore0", "g.b", ("ts", "y"),
                           capacity=512, ts_field="ts", max_delay=0.0,
                           shards=2, num_engines=2)
    n = 256
    p.append({"v": rng.normal(size=n), "w": rng.normal(size=n)})
    ts = np.sort(rng.uniform(0, 100, size=n))
    s.append({"ts": ts, "x": rng.normal(size=n)})
    s.flush()
    a.append({"ts": ts, "x": rng.normal(size=n)})
    b.append({"ts": ts + rng.uniform(-0.3, 0.3, size=n),
              "y": rng.normal(size=n)})
    a.flush()
    b.flush()
    return bd


QUERIES = [
    "bdstream(window(g.p, 64))",
    "bdstream(window(g.p, 64, 16))",
    "bdstream(ewindow(g.s, 20, 10))",
    "bdstream(aggregate(window(g.p, 32), count(*)))",
    "bdstream(aggregate(window(g.p, 32), sum(v)))",
    "bdstream(aggregate(window(g.p, 32), avg(v)))",
    "bdstream(aggregate(window(g.p, 32), min(w)))",
    "bdstream(aggregate(window(g.p, 32), max(w)))",
    "bdstream(aggregate(window(g.p, 64, 16), max(v)))",
    "bdstream(aggregate(ewindow(g.s, 20, 10), sum(x)))",
    "bdstream(join(ewindow(g.s, 40, 20), ewindow(g.s, 40, 20),"
    " on=ts, tol=0.5))",
    "bdstream(join(ewindow(g.a, 40, 20), ewindow(g.b, 40, 20),"
    " on=ts, tol=0.25))",
]


def columns(value):
    return dict(getattr(value, "columns", None) or value.attrs)


def main() -> int:
    from repro.stream import compile as query_compile

    if not query_compile.JAX_AVAILABLE:
        print("FAIL: jax unavailable — the jit-parity gate needs the "
              "compiled path importable")
        return 1
    bd = build()
    bad = 0
    for query in QUERIES:
        os.environ[query_compile.BACKEND_ENV] = "interpreter"
        ref = bd.query(query).value
        query_compile.reset_stats()
        os.environ[query_compile.BACKEND_ENV] = "jit"
        got = bd.query(query).value
        stats = query_compile.stats()
        errs = []
        if stats["fallbacks"]:
            errs.append(f"fallbacks={stats['fallbacks']} "
                        f"({stats['fallback_reasons']})")
        if not stats["executions"]:
            errs.append("compiled path did not serve the query")
        r_cols, g_cols = columns(ref), columns(got)
        if list(r_cols) != list(g_cols):
            errs.append(f"column order {list(r_cols)} != {list(g_cols)}")
        else:
            for k in r_cols:
                rv = np.asarray(r_cols[k])
                gv = np.asarray(g_cols[k])
                if rv.dtype != gv.dtype:
                    errs.append(f"[{k}] dtype {rv.dtype} != {gv.dtype}")
                elif rv.shape != gv.shape:
                    errs.append(f"[{k}] shape {rv.shape} != {gv.shape}")
                elif not np.array_equal(rv, gv):
                    errs.append(f"[{k}] values diverge")
        if errs:
            bad += 1
            print(f"DIVERGED {query}")
            for e in errs:
                print(f"  - {e}")
        else:
            print(f"ok {query}")
    print(("FAIL" if bad else "OK") + f": {len(QUERIES) - bad}/"
          f"{len(QUERIES)} queries bit-identical across backends")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
