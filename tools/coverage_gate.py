"""Tier-1 line-coverage gate for the streaming + core middleware.

Runs the tier-1 pytest suite with line coverage over
``src/repro/stream/`` and ``src/repro/core/`` and fails when the
combined percentage drops below the floor committed in
``pyproject.toml`` (``[tool.repro] coverage_floor``):

  PYTHONPATH=src python tools/coverage_gate.py [--floor N] [pytest args]

Two measurement backends, same gate:

* **pytest-cov** (CI: installed via the ``cov`` extra) — the canonical
  number the committed floor is calibrated against.
* **stdlib tracer fallback** — when pytest-cov is absent (the dev
  container bakes no extra wheels), a ``sys.monitoring`` /
  ``sys.settrace`` tracer collects executed lines in-process and the
  denominator comes from each module's code-object line tables.  Close
  to pytest-cov's number but not identical (it cannot see lines run
  only at import time before tracing starts, and counts line tables
  slightly differently) — treat it as a calibration aid, and refresh
  the floor from CI's pytest-cov output (procedure in
  docs/OPERATIONS.md).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import tempfile
from typing import Dict, Iterable, Set

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TARGET_DIRS = ("src/repro/stream", "src/repro/core")
COV_PACKAGES = ("repro.stream", "repro.core")


def committed_floor() -> float:
    """The [tool.repro] coverage_floor value from pyproject.toml (a
    small regex parse: python 3.10 has no stdlib TOML reader)."""
    text = open(os.path.join(REPO, "pyproject.toml")).read()
    m = re.search(r"^\[tool\.repro\]\s*$(.*?)(?:^\[|\Z)", text,
                  re.MULTILINE | re.DOTALL)
    if not m:
        raise SystemExit("pyproject.toml has no [tool.repro] section")
    f = re.search(r"^coverage_floor\s*=\s*([0-9.]+)", m.group(1),
                  re.MULTILINE)
    if not f:
        raise SystemExit("[tool.repro] has no coverage_floor")
    return float(f.group(1))


def target_files() -> Set[str]:
    files = set()
    for d in TARGET_DIRS:
        for root, _, names in os.walk(os.path.join(REPO, d)):
            files.update(os.path.join(root, n) for n in names
                         if n.endswith(".py"))
    return files


def executable_lines(path: str) -> Set[str]:
    """Line numbers the compiler placed in the module's code-object
    line tables (the denominator of the stdlib backend)."""
    code = compile(open(path).read(), path, "exec")
    lines: Set[int] = set()
    stack = [code]
    while stack:
        co = stack.pop()
        lines.update(ln for _, ln in __import__("dis")
                     .findlinestarts(co) if ln is not None)
        stack.extend(c for c in co.co_consts if hasattr(c, "co_code"))
    return lines


def run_pytest_cov(pytest_args: Iterable[str]) -> float:
    """Run tier-1 under pytest-cov; returns the combined percent over
    the target packages (the canonical gate number)."""
    report = os.path.join(tempfile.mkdtemp(), "coverage.json")
    cmd = [sys.executable, "-m", "pytest", "-q",
           *(f"--cov={p}" for p in COV_PACKAGES),
           f"--cov-report=json:{report}", *pytest_args]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(cmd, cwd=REPO, env=env)
    if proc.returncode not in (0,):
        raise SystemExit(f"tier-1 tests failed (exit {proc.returncode}) "
                         f"— fix the tests before reading coverage")
    with open(report) as fh:
        data = json.load(fh)
    totals = data["totals"]
    return float(totals["percent_covered"])


def run_stdlib_tracer(pytest_args: Iterable[str]) -> float:
    """In-process fallback: trace executed lines of the target files
    while pytest runs, denominator from the compiler's line tables."""
    import threading

    files = target_files()
    executed: Dict[str, Set[int]] = {p: set() for p in files}

    if sys.version_info >= (3, 12):
        mon = sys.monitoring
        tool = mon.COVERAGE_ID
        mon.use_tool_id(tool, "coverage-gate")

        def on_line(code, line):
            fn = code.co_filename
            if fn in executed:
                executed[fn].add(line)
            else:
                return mon.DISABLE
            return None

        mon.register_callback(tool, mon.events.LINE, on_line)
        mon.set_events(tool, mon.events.LINE)
    else:
        def tracer(frame, event, arg):
            if frame.f_code.co_filename not in executed:
                return None              # skip this frame entirely

            def line_tracer(fr, ev, a):
                if ev == "line":
                    executed[fr.f_code.co_filename].add(fr.f_lineno)
                return line_tracer

            if event == "line":
                executed[frame.f_code.co_filename].add(frame.f_lineno)
            return line_tracer

        threading.settrace(tracer)
        sys.settrace(tracer)

    import pytest
    rc = pytest.main(["-q", "-p", "no:cacheprovider", *pytest_args])

    if sys.version_info >= (3, 12):
        sys.monitoring.set_events(sys.monitoring.COVERAGE_ID, 0)
        sys.monitoring.free_tool_id(sys.monitoring.COVERAGE_ID)
    else:
        sys.settrace(None)
        threading.settrace(None)         # type: ignore[arg-type]
    if rc != 0:
        raise SystemExit(f"tier-1 tests failed (exit {rc}) — fix the "
                         f"tests before reading coverage")

    total_exec = total_hit = 0
    for path in sorted(files):
        lines = executable_lines(path)
        hits = executed[path] & lines
        total_exec += len(lines)
        total_hit += len(hits)
        rel = os.path.relpath(path, REPO)
        pct = 100.0 * len(hits) / len(lines) if lines else 100.0
        print(f"  {rel:<44} {pct:5.1f}% ({len(hits)}/{len(lines)})")
    return 100.0 * total_hit / total_exec if total_exec else 100.0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--floor", type=float, default=None,
                    help="override the committed coverage floor")
    ap.add_argument("pytest_args", nargs="*",
                    help="extra args forwarded to pytest")
    args = ap.parse_args()
    floor = args.floor if args.floor is not None else committed_floor()
    try:
        import pytest_cov                              # noqa: F401
        backend = "pytest-cov"
        percent = run_pytest_cov(args.pytest_args)
    except ImportError:
        backend = "stdlib-tracer (calibration aid — CI uses pytest-cov)"
        sys.path.insert(0, os.path.join(REPO, "src"))
        percent = run_stdlib_tracer(args.pytest_args)
    status = "OK" if percent >= floor else "FAIL"
    print(f"coverage[{backend}] src/repro/{{stream,core}}: "
          f"{percent:.2f}% (floor {floor:.1f}%) -> {status}")
    return 0 if percent >= floor else 3


if __name__ == "__main__":
    sys.exit(main())
